//! Vectorizable noisy-GEMM kernels for the native analog backend.
//!
//! The clean matmul is a cache-blocked `ikj` loop (row-major weights,
//! contiguous channel-axis inner loop, so the compiler auto-vectorizes
//! the accumulation); noise is applied on top per the paper's models:
//!
//! - every output channel `c` carries additive Gaussian noise whose
//!   one-repetition variance follows Eq. 9 (thermal form, with the shot
//!   sigma folded to `1/sqrt(photons_per_aj)` for homodyne devices);
//! - crossbar devices add weight read noise: a per-entry Gaussian
//!   perturbation `dW` applied through a second GEMM (Eq. 10);
//! - K-repetition averaging (paper Fig. 3) divides every noise variance
//!   by the channel's redundancy `K_c`. Averaging K i.i.d. Gaussian
//!   executions is *in distribution* identical to a single execution
//!   with every noise std scaled by `1/sqrt(K_c)`, so the kernel folds
//!   the repetitions into one pass instead of paying K x the FLOPs —
//!   the cycles/energy ledger still charges the full K repetitions.

use crate::analog::{HardwareConfig, NoiseKind};
use crate::quant::noise_bits::thermal_var;
use crate::runtime::artifact::{ModelMeta, SiteMeta};
use crate::util::rng::Rng;

/// k-dimension block size for the clean GEMM: 64 f32 rows of a
/// 256-channel layer keep the working set comfortably inside L1.
const K_BLOCK: usize = 64;

/// `out[b, j] += sum_k x[b, k] * w[k, j]` for row-major
/// `x: [batch, n_dot]`, `w: [n_dot, n_channels]`,
/// `out: [batch, n_channels]`. The caller zeroes (or pre-loads) `out`.
pub fn gemm_blocked(
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    batch: usize,
    n_dot: usize,
    n_channels: usize,
) {
    debug_assert_eq!(x.len(), batch * n_dot);
    debug_assert_eq!(w.len(), n_dot * n_channels);
    debug_assert_eq!(out.len(), batch * n_channels);
    for b in 0..batch {
        let xrow = &x[b * n_dot..(b + 1) * n_dot];
        let orow = &mut out[b * n_channels..(b + 1) * n_channels];
        let mut kk = 0;
        while kk < n_dot {
            let kend = (kk + K_BLOCK).min(n_dot);
            for k in kk..kend {
                let xv = xrow[k];
                let wrow = &w[k * n_channels..(k + 1) * n_channels];
                for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                    *o += xv * wv;
                }
            }
            kk = kend;
        }
    }
}

/// One-repetition (K = 1) noise parameters of a site on a device: the
/// additive output-noise std per channel, and the per-entry weight
/// read-noise std (crossbar only, 0 elsewhere). One repetition spends
/// `hw.base_energy_aj` per MAC, so that energy sets the noise floor
/// that K-averaging then divides down.
#[derive(Clone, Copy, Debug)]
pub struct SiteNoise {
    pub additive_std: f64,
    pub weight_std: f64,
}

/// Noise model selection per `DeviceModel` (paper Sec. II-C):
/// homodyne = shot, broadcast-and-weight = thermal, crossbar =
/// thermal + weight read noise.
pub fn site_noise(
    kind: NoiseKind,
    site: &SiteMeta,
    meta: &ModelMeta,
    hw: &HardwareConfig,
) -> SiteNoise {
    let e1 = hw.base_energy_aj.max(f64::MIN_POSITIVE);
    match kind {
        NoiseKind::Shot => {
            // Fold shot noise into the sigma/sqrt(E) form the artifacts
            // use: detected photons per MAC = E * photons_per_aj, and
            // SNR grows with sqrt(photons).
            let sigma_shot = 1.0 / meta.photons_per_aj.max(1e-12).sqrt();
            SiteNoise {
                additive_std: thermal_var(site, sigma_shot, e1, true).sqrt(),
                weight_std: 0.0,
            }
        }
        NoiseKind::Thermal => SiteNoise {
            additive_std: thermal_var(site, meta.sigma_thermal, e1, true)
                .sqrt(),
            weight_std: 0.0,
        },
        NoiseKind::Weight => SiteNoise {
            // Crossbars carry thermal noise on top of the conductance
            // read error; the per-weight std follows Eq. 10 (weight_var
            // is that std squared through the dot product).
            additive_std: thermal_var(site, meta.sigma_thermal, e1, true)
                .sqrt(),
            // Per-weight std per Eq. 10 (`noise_bits::weight_var` is
            // this std squared pushed through the dot product).
            weight_std: (site.w_hi_layer - site.w_lo_layer)
                * meta.sigma_weight
                / e1.sqrt(),
        },
    }
}

/// Add i.i.d. Gaussian noise of std `additive_std / sqrt(K_c)` to every
/// output channel. `ks` is either one uniform K (time/spatial
/// averaging) or one K per channel (per-row spatial averaging).
pub fn apply_additive_noise(
    out: &mut [f32],
    n_channels: usize,
    ks: &[f64],
    additive_std: f64,
    rng: &mut Rng,
) {
    if additive_std <= 0.0 {
        return;
    }
    debug_assert!(ks.len() == 1 || ks.len() == n_channels);
    for row in out.chunks_exact_mut(n_channels) {
        for (j, o) in row.iter_mut().enumerate() {
            let k = ks[if ks.len() == 1 { 0 } else { j }].max(1.0);
            *o += (rng.gaussian() * additive_std / k.sqrt()) as f32;
        }
    }
}

/// Apply weight read noise: draw a per-entry perturbation `dW` with
/// std `weight_std / sqrt(K_c)` (column c folds its own redundancy) and
/// accumulate `x * dW` into `out` through the blocked GEMM. The draw is
/// per dispatched batch — each repetition re-reads the array, and the
/// K-fold average is folded into the std exactly as for additive noise.
#[allow(clippy::too_many_arguments)]
pub fn apply_weight_noise(
    x: &[f32],
    out: &mut [f32],
    batch: usize,
    n_dot: usize,
    n_channels: usize,
    ks: &[f64],
    weight_std: f64,
    rng: &mut Rng,
) {
    if weight_std <= 0.0 {
        return;
    }
    debug_assert!(ks.len() == 1 || ks.len() == n_channels);
    let mut dw = vec![0.0f32; n_dot * n_channels];
    for (i, d) in dw.iter_mut().enumerate() {
        let k = ks[if ks.len() == 1 { 0 } else { i % n_channels }].max(1.0);
        *d = (rng.gaussian() * weight_std / k.sqrt()) as f32;
    }
    gemm_blocked(x, &dw, out, batch, n_dot, n_channels);
}

/// Stuck/dead physical-tile faults an analog engine must suffer, as
/// bitmasks over physical tile ids (tile `t` maps to bit `t % 64`).
/// Injected via `coordinator::Fault::{StuckCell, DeadTile}` and carried
/// to the engine through `ExecutionBackend::set_tile_faults`; the
/// corruption is derived from `stuck_seed`, never from wall time, so
/// replays under `VirtualClock` are bit-identical.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TileFaults {
    /// Tiles with permanently stuck weight cells.
    pub stuck_mask: u64,
    /// Seed for the deterministic stuck-cell pattern.
    pub stuck_seed: u64,
    /// Tiles that are dead outright (replica outputs read zero).
    pub dead_mask: u64,
}

impl TileFaults {
    pub fn is_clean(&self) -> bool {
        self.stuck_mask == 0 && self.dead_mask == 0
    }
}

/// Physical tile id hosting replica `group` of site `site` when each
/// site spreads over `groups` redundant tiles: a fixed round-robin
/// layout, so a fault injected at one tile id lands on one known
/// (site, replica) pair in every batch.
pub fn phys_tile(site: usize, group: usize, groups: usize) -> u32 {
    ((site * groups.max(1) + group) % 64) as u32
}

/// Corrupt `out` as if a sparse, deterministic set of weight cells in
/// this tile were stuck at `w_stuck`: for each stuck cell `(i, j)` the
/// served output gains `x[b, i] * (w_stuck - w[i, j])`. Cell positions
/// derive from `seed` alone (stable across batches — a stuck cell
/// stays stuck), covering ~1/64 of the tile's cells.
#[allow(clippy::too_many_arguments)]
pub fn apply_stuck_cells(
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    batch: usize,
    n_dot: usize,
    n_channels: usize,
    w_stuck: f32,
    seed: u64,
) {
    debug_assert_eq!(w.len(), n_dot * n_channels);
    let n_stuck = (n_dot * n_channels / 64).max(1);
    let mut rng = Rng::new(seed);
    for _ in 0..n_stuck {
        let i = rng.below(n_dot as u64) as usize;
        let j = rng.below(n_channels as u64) as usize;
        let dw = w_stuck - w[i * n_channels + j];
        for b in 0..batch {
            out[b * n_channels + j] += x[b * n_dot + i] * dw;
        }
    }
}

/// Cycle (and clip) an arbitrary-length feature row into a site's
/// `n_dot`-element input vector. Token ids (I32 features) are first
/// hashed to a deterministic embedding in [-1, 1].
pub fn embed_row_f32(
    src: &[f32],
    dst: &mut [f32],
    lo: f32,
    hi: f32,
) {
    // Panic-free clamp: `f32::clamp` asserts lo <= hi, and clip bounds
    // come from artifact metadata — `ModelMeta::parse` validates them,
    // but a malformed range must shed a batch, never a fleet worker.
    let (lo, hi) = if lo <= hi { (lo, hi) } else { (hi, lo) };
    let n = src.len().max(1);
    for (k, d) in dst.iter_mut().enumerate() {
        let v = if src.is_empty() { 0.0 } else { src[k % n] };
        *d = v.min(hi).max(lo);
    }
}

/// Deterministic token embedding: hash the id through splitmix64 onto
/// [-1, 1] so NLP-shaped (I32) requests exercise the same GEMM path.
pub fn embed_token(id: i32) -> f32 {
    let mut s = (id as i64 as u64) ^ 0x9E37_79B9_7F4A_7C15;
    let h = crate::util::rng::splitmix64(&mut s);
    ((h >> 11) as f64 * (1.0 / (1u64 << 53) as f64) * 2.0 - 1.0) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_matches_naive() {
        let (batch, n_dot, n_channels) = (3, 70, 5); // crosses a K_BLOCK edge
        let mut rng = Rng::new(7);
        let x: Vec<f32> =
            (0..batch * n_dot).map(|_| rng.gaussian() as f32).collect();
        let w: Vec<f32> = (0..n_dot * n_channels)
            .map(|_| rng.gaussian() as f32)
            .collect();
        let mut out = vec![0.0f32; batch * n_channels];
        gemm_blocked(&x, &w, &mut out, batch, n_dot, n_channels);
        for b in 0..batch {
            for j in 0..n_channels {
                let want: f32 = (0..n_dot)
                    .map(|k| x[b * n_dot + k] * w[k * n_channels + j])
                    .sum();
                let got = out[b * n_channels + j];
                assert!(
                    (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "[{b},{j}] {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn additive_noise_scales_inverse_sqrt_k() {
        // Pure kernel-level check of the paper's averaging law: the
        // measured std of the injected noise at K vs 4K must shrink 2x.
        let n = 20_000;
        let std_at = |k: f64, seed: u64| -> f64 {
            let mut rng = Rng::new(seed);
            let mut buf = vec![0.0f32; n];
            apply_additive_noise(&mut buf, 1, &[k], 1.0, &mut rng);
            (buf.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
                / n as f64)
                .sqrt()
        };
        let s1 = std_at(1.0, 11);
        let s4 = std_at(4.0, 12);
        let s16 = std_at(16.0, 13);
        assert!((s1 / s4 - 2.0).abs() < 0.1, "s1/s4 = {}", s1 / s4);
        assert!((s4 / s16 - 2.0).abs() < 0.1, "s4/s16 = {}", s4 / s16);
    }

    #[test]
    fn per_channel_k_applies_per_column() {
        // Channel 0 at K=1, channel 1 at K=100: channel 1's noise must
        // be ~10x smaller.
        let rows = 8_000;
        let mut rng = Rng::new(3);
        let mut buf = vec![0.0f32; rows * 2];
        apply_additive_noise(&mut buf, 2, &[1.0, 100.0], 1.0, &mut rng);
        let mut v = [0.0f64; 2];
        for row in buf.chunks_exact(2) {
            v[0] += (row[0] as f64).powi(2);
            v[1] += (row[1] as f64).powi(2);
        }
        let ratio = (v[0] / v[1]).sqrt();
        assert!((ratio - 10.0).abs() < 1.0, "ratio {ratio}");
    }

    #[test]
    fn weight_noise_correlates_through_the_dot_product() {
        // With x = ones, each output is sum of n_dot i.i.d. dW entries:
        // std = sqrt(n_dot) * weight_std / sqrt(K). dW is drawn once per
        // dispatched batch (quasi-static read error), so independent
        // draws come from separate calls, not separate batch lanes.
        let (draws, n_dot) = (4_000u64, 16);
        let x = vec![1.0f32; n_dot];
        let mut sum2 = 0.0f64;
        for d in 0..draws {
            let mut rng = Rng::new(1000 + d);
            let mut out = vec![0.0f32; 1];
            apply_weight_noise(
                &x, &mut out, 1, n_dot, 1, &[4.0], 0.5, &mut rng,
            );
            sum2 += (out[0] as f64).powi(2);
        }
        let std = (sum2 / draws as f64).sqrt();
        let want = (n_dot as f64).sqrt() * 0.5 / 2.0;
        assert!((std / want - 1.0).abs() < 0.1, "std {std} want {want}");
    }

    #[test]
    fn weight_noise_is_quasi_static_within_a_batch() {
        // Every lane of one dispatched batch sees the same dW draw.
        let (batch, n_dot) = (4, 8);
        let mut rng = Rng::new(5);
        let x = vec![1.0f32; batch * n_dot];
        let mut out = vec![0.0f32; batch];
        apply_weight_noise(
            &x, &mut out, batch, n_dot, 1, &[1.0], 0.5, &mut rng,
        );
        assert!(out.iter().all(|&v| v == out[0]));
        assert_ne!(out[0], 0.0);
    }

    #[test]
    fn stuck_cells_are_deterministic_and_batch_stable() {
        let (batch, n_dot, n_channels) = (3, 16, 4);
        let mut rng = Rng::new(9);
        let x: Vec<f32> =
            (0..batch * n_dot).map(|_| rng.gaussian() as f32).collect();
        let w: Vec<f32> = (0..n_dot * n_channels)
            .map(|_| rng.gaussian() as f32)
            .collect();
        let run = |seed: u64| {
            let mut out = vec![0.0f32; batch * n_channels];
            apply_stuck_cells(
                &x, &w, &mut out, batch, n_dot, n_channels, 0.5, seed,
            );
            out
        };
        assert_eq!(run(7), run(7), "same seed -> same stuck pattern");
        assert_ne!(run(7), run(8), "different seed -> different cells");
        assert!(run(7).iter().any(|&v| v != 0.0), "fault must bite");
    }

    #[test]
    fn phys_tile_layout_is_stable_and_bounded() {
        assert_eq!(phys_tile(0, 0, 3), 0);
        assert_eq!(phys_tile(0, 2, 3), 2);
        assert_eq!(phys_tile(1, 0, 3), 3);
        assert_eq!(phys_tile(1, 0, 1), 1);
        for s in 0..100 {
            for g in 0..5 {
                assert!(phys_tile(s, g, 5) < 64);
            }
        }
    }

    #[test]
    fn tile_faults_default_is_clean() {
        assert!(TileFaults::default().is_clean());
        let f = TileFaults { stuck_mask: 2, stuck_seed: 1, dead_mask: 0 };
        assert!(!f.is_clean());
    }

    #[test]
    fn embed_cycles_and_clips() {
        let mut dst = vec![0.0f32; 5];
        embed_row_f32(&[0.5, 9.0], &mut dst, -1.0, 1.0);
        assert_eq!(dst, vec![0.5, 1.0, 0.5, 1.0, 0.5]);
        let t = embed_token(42);
        assert!((-1.0..=1.0).contains(&t));
        assert_eq!(t, embed_token(42), "deterministic");
        assert_ne!(embed_token(42), embed_token(43));
    }
}
