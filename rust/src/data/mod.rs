//! Dataset loading: frozen splits (exported by `make artifacts`) and
//! seeded synthetic sets for the artifact-free native path.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::util::dpt;
use crate::util::rng::Rng;

/// Input features: images (f32) or token ids (i32).
#[derive(Clone, Debug)]
pub enum Features {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// An evaluation/training split.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub n: usize,
    /// Per-sample feature element count.
    pub sample_size: usize,
    /// Feature dims per sample (e.g. [24, 24, 3] or [32]).
    pub sample_dims: Vec<usize>,
    pub x: Features,
    pub y: Vec<i32>,
}

impl Dataset {
    /// Load `<kind>.eval.bin` / `<kind>.trainsub.bin`.
    pub fn load(dir: &Path, kind: &str, split: &str) -> Result<Dataset> {
        let path = dir.join(format!("{kind}.{split}.bin"));
        let tensors = dpt::read(&path)?;
        let xt = tensors.get("x").ok_or_else(|| anyhow!("missing x"))?;
        let yt = tensors.get("y").ok_or_else(|| anyhow!("missing y"))?;
        let n = xt.shape[0];
        if yt.shape != vec![n] {
            bail!("y shape mismatch: {:?} vs n={n}", yt.shape);
        }
        let sample_dims = xt.shape[1..].to_vec();
        let sample_size: usize = sample_dims.iter().product();
        let x = match &xt.data {
            dpt::Data::F32(v) => Features::F32(v.clone()),
            dpt::Data::I32(v) => Features::I32(v.clone()),
            _ => bail!("unsupported feature dtype"),
        };
        let y = yt
            .data
            .as_i32()
            .ok_or_else(|| anyhow!("labels not i32"))?
            .to_vec();
        Ok(Dataset { n, sample_size, sample_dims, x, y })
    }

    /// Seeded synthetic feature set: `n` samples of `sample_size`
    /// values drawn uniformly from `[lo, hi]`. Labels start at zero —
    /// pair with [`Dataset::with_labels`] (e.g.
    /// `NativeOps::synthetic_dataset` labels with the clean native
    /// model's own predictions, so the fp baseline is exact by
    /// construction). Same seed, same dataset, on every platform.
    pub fn synthetic_features(
        n: usize,
        sample_size: usize,
        lo: f32,
        hi: f32,
        seed: u64,
    ) -> Result<Dataset> {
        if n == 0 || sample_size == 0 {
            bail!("synthetic dataset needs n > 0 and sample_size > 0");
        }
        if lo > hi || !lo.is_finite() || !hi.is_finite() {
            bail!("synthetic feature range {lo}..{hi} is not ordered");
        }
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * sample_size)
            .map(|_| rng.uniform_in(lo as f64, hi as f64) as f32)
            .collect();
        Ok(Dataset {
            n,
            sample_size,
            sample_dims: vec![sample_size],
            x: Features::F32(x),
            y: vec![0; n],
        })
    }

    /// Replace the labels (length-checked: one label per sample).
    pub fn with_labels(mut self, y: Vec<i32>) -> Result<Dataset> {
        if y.len() != self.n {
            bail!("{} labels for {} samples", y.len(), self.n);
        }
        self.y = y;
        Ok(self)
    }

    /// Number of complete batches of size `b`.
    pub fn n_batches(&self, b: usize) -> usize {
        self.n / b
    }

    /// Feature slice for batch `i` of size `b`.
    pub fn batch_x(&self, i: usize, b: usize) -> Features {
        let (s, e) = (i * b * self.sample_size, (i + 1) * b * self.sample_size);
        match &self.x {
            Features::F32(v) => Features::F32(v[s..e].to_vec()),
            Features::I32(v) => Features::I32(v[s..e].to_vec()),
        }
    }

    /// Label slice for batch `i` of size `b`.
    pub fn batch_y(&self, i: usize, b: usize) -> &[i32] {
        &self.y[i * b..(i + 1) * b]
    }

    /// Feature slice for a single sample (serving path).
    pub fn sample_x(&self, i: usize) -> Features {
        let (s, e) = (i * self.sample_size, (i + 1) * self.sample_size);
        match &self.x {
            Features::F32(v) => Features::F32(v[s..e].to_vec()),
            Features::I32(v) => Features::I32(v[s..e].to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn fake_dataset(dir: &Path) {
        let mut m = BTreeMap::new();
        let n = 8;
        let x: Vec<f32> = (0..n * 6).map(|i| i as f32).collect();
        m.insert("x".into(), dpt::Tensor::f32(vec![n, 2, 3], x));
        m.insert("y".into(), dpt::Tensor::i32(vec![n], (0..n as i32).collect()));
        dpt::write(&dir.join("vision.eval.bin"), &m).unwrap();
    }

    #[test]
    fn load_and_batch() {
        let dir = std::env::temp_dir().join("dynaprec_data_test");
        std::fs::create_dir_all(&dir).unwrap();
        fake_dataset(&dir);
        let d = Dataset::load(&dir, "vision", "eval").unwrap();
        assert_eq!(d.n, 8);
        assert_eq!(d.sample_size, 6);
        assert_eq!(d.sample_dims, vec![2, 3]);
        assert_eq!(d.n_batches(4), 2);
        match d.batch_x(1, 4) {
            Features::F32(v) => {
                assert_eq!(v.len(), 24);
                assert_eq!(v[0], 24.0);
            }
            _ => panic!("wrong dtype"),
        }
        assert_eq!(d.batch_y(1, 4), &[4, 5, 6, 7]);
    }

    #[test]
    fn synthetic_features_are_seeded_and_bounded() {
        let a = Dataset::synthetic_features(16, 5, -1.0, 1.0, 42).unwrap();
        let b = Dataset::synthetic_features(16, 5, -1.0, 1.0, 42).unwrap();
        assert_eq!(a.n, 16);
        assert_eq!(a.sample_size, 5);
        match (&a.x, &b.x) {
            (Features::F32(u), Features::F32(v)) => {
                assert_eq!(u, v, "same seed, same features");
                assert!(u.iter().all(|&x| (-1.0..=1.0).contains(&x)));
            }
            _ => panic!("synthetic features are f32"),
        }
        let c = Dataset::synthetic_features(16, 5, -1.0, 1.0, 43).unwrap();
        match (&a.x, &c.x) {
            (Features::F32(u), Features::F32(v)) => assert_ne!(u, v),
            _ => unreachable!(),
        }
        // Degenerate shapes and reversed ranges error cleanly.
        assert!(Dataset::synthetic_features(0, 5, 0.0, 1.0, 0).is_err());
        assert!(Dataset::synthetic_features(4, 0, 0.0, 1.0, 0).is_err());
        assert!(Dataset::synthetic_features(4, 5, 1.0, -1.0, 0).is_err());
    }

    #[test]
    fn with_labels_checks_length() {
        let d = Dataset::synthetic_features(4, 2, 0.0, 1.0, 1).unwrap();
        assert!(d.clone().with_labels(vec![1; 3]).is_err());
        let d = d.with_labels(vec![3, 2, 1, 0]).unwrap();
        assert_eq!(d.y, vec![3, 2, 1, 0]);
    }
}
