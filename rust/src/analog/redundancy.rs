//! Redundant-coding planner (paper Sec. IV, Fig. 3).
//!
//! Given per-layer energies (relative to the device's base energy/MAC),
//! choose a redundancy factor K per layer and account for its cost:
//!
//!   Fig. 3a  time averaging     — repeat the MVM K cycles, average:
//!            cycles x K, area x 1, energy x K
//!   Fig. 3b  spatial averaging  — K device copies of (W, x):
//!            cycles x 1, area x K, energy x K
//!   Fig. 3c  per-row spatial    — row i replicated K_i times:
//!            cycles x 1, area x sum(K_i)/rows, energy x sum(K_i * macs_i)
//!
//! Averaging K i.i.d. executions divides noise variance by K, so K = E
//! (energies are continuous in the paper's ideal case; `quantized`
//! rounds K up to whole repetitions, the realizable schedule).

use super::device::HardwareConfig;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AveragingMode {
    Time,
    Spatial,
    PerRowSpatial,
}

/// Cost of executing one layer's MVM stream at the requested precision.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub mode: AveragingMode,
    /// Redundancy per output channel (len 1 for uniform/time/spatial).
    pub k_per_channel: Vec<f64>,
    /// Cycles per input vector (relative to 1 at K = 1).
    pub cycles: f64,
    /// Device-area multiplier (tiles x replication), relative to K = 1.
    pub area: f64,
    /// Energy for the layer per sample, in base-energy units (aJ for
    /// homodyne): sum over channels of K_c * macs_c.
    pub energy: f64,
    /// Physical tiles occupied (before replication).
    pub base_tiles: usize,
}

/// Plan one layer. `e_per_channel` are energies in base-energy units;
/// `macs_per_channel` is MACs per sample per channel; `quantized` rounds
/// K up to integers (realizable redundancy).
pub fn plan_layer(
    hw: &HardwareConfig,
    mode: AveragingMode,
    e_per_channel: &[f64],
    n_dot: usize,
    macs_per_channel: f64,
    quantized: bool,
) -> LayerPlan {
    assert!(!e_per_channel.is_empty());
    let base_tiles = hw.tiles_for(n_dot, e_per_channel.len());
    let k_of = |e: f64| -> f64 {
        let k = (e / hw.base_energy_aj).max(f64::MIN_POSITIVE);
        if quantized {
            k.ceil().max(1.0)
        } else {
            k
        }
    };
    match mode {
        AveragingMode::Time | AveragingMode::Spatial => {
            // Uniform K across the layer: take the max requested channel
            // energy (precision is set by the most demanding channel).
            let k = e_per_channel.iter().copied().fold(0.0, f64::max);
            let k = k_of(k);
            let energy = k * macs_per_channel * e_per_channel.len() as f64;
            let (cycles, area) = match mode {
                AveragingMode::Time => (k, base_tiles as f64),
                _ => (1.0, base_tiles as f64 * k),
            };
            LayerPlan {
                mode,
                k_per_channel: vec![k],
                cycles,
                area,
                energy,
                base_tiles,
            }
        }
        AveragingMode::PerRowSpatial => {
            let ks: Vec<f64> = e_per_channel.iter().map(|&e| k_of(e)).collect();
            let sum_k: f64 = ks.iter().sum();
            let mean_k = sum_k / ks.len() as f64;
            let energy: f64 = ks.iter().map(|k| k * macs_per_channel).sum();
            LayerPlan {
                mode,
                cycles: 1.0,
                area: base_tiles as f64 * mean_k,
                energy,
                base_tiles,
                k_per_channel: ks,
            }
        }
    }
}

/// How redundant replicas of a tile are combined back into one value.
///
/// `Median` masks corrupted replicas *exactly* as long as at most
/// [`fault_budget`] of them are faulty (the clean values outnumber the
/// corrupt ones around the middle of the order statistics). `Average`
/// has no masking guarantee — a corrupt replica leaks into the result
/// attenuated by 1/n — but preserves the unbiased-mean noise model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeMode {
    Median,
    Average,
}

/// Max number of corrupted replicas an n-replica `Median` decode masks
/// exactly: floor((n - 1) / 2).
pub fn fault_budget(n: usize) -> usize {
    n.saturating_sub(1) / 2
}

/// Encode a tile (any flat value block) into `n` redundant replicas.
/// Replicas are value-identical; fault isolation comes from mapping
/// each replica to a distinct physical tile.
pub fn encode_replicas(tile: &[f32], n: usize) -> Vec<Vec<f32>> {
    assert!(n >= 1, "need at least one replica");
    (0..n).map(|_| tile.to_vec()).collect()
}

/// Replica counts up to this many decode with a stack-resident order
/// buffer — no allocation at all. Real deployments replicate 3–5-way;
/// anything beyond the stack bound falls back to one heap buffer per
/// call.
const STACK_REPLICAS: usize = 16;

fn combine(vals: &mut [f32], mode: DecodeMode) -> f32 {
    match mode {
        DecodeMode::Median => median_of(vals),
        DecodeMode::Average => {
            let sum: f64 = vals.iter().map(|&v| v as f64).sum();
            (sum / vals.len() as f64) as f32
        }
    }
}

fn decode_impl<R: AsRef<[f32]>>(
    out: &mut [f32],
    replicas: &[R],
    mode: DecodeMode,
) {
    assert!(!replicas.is_empty());
    for r in replicas {
        assert_eq!(r.as_ref().len(), out.len(), "replica length mismatch");
    }
    let n = replicas.len();
    if n == 1 {
        out.copy_from_slice(replicas[0].as_ref());
        return;
    }
    let mut stack = [0.0f32; STACK_REPLICAS];
    let mut heap: Vec<f32>;
    let scratch: &mut [f32] = if n <= STACK_REPLICAS {
        &mut stack[..n]
    } else {
        heap = vec![0.0f32; n];
        &mut heap
    };
    for (i, o) in out.iter_mut().enumerate() {
        for (s, r) in scratch.iter_mut().zip(replicas) {
            *s = r.as_ref()[i];
        }
        *o = combine(scratch, mode);
    }
}

/// Decode replica views element-wise into `out` (all lengths must
/// match). `out` is reused across batches; up to [`STACK_REPLICAS`]
/// replicas decode with zero allocation.
pub fn decode_replicas_into(
    out: &mut [f32],
    replicas: &[&[f32]],
    mode: DecodeMode,
) {
    decode_impl(out, replicas, mode);
}

/// [`decode_replicas_into`] over owned replica buffers — the hot-path
/// form the native kernel feeds its per-site scratch replicas to, with
/// no per-call view vector.
pub fn decode_replica_buffers_into(
    out: &mut [f32],
    replicas: &[Vec<f32>],
    mode: DecodeMode,
) {
    decode_impl(out, replicas, mode);
}

/// Decode replicas element-wise, returning a fresh buffer.
pub fn decode_replicas(replicas: &[Vec<f32>], mode: DecodeMode) -> Vec<f32> {
    let mut out = vec![0.0f32; replicas[0].len()];
    decode_impl(&mut out, replicas, mode);
    out
}

fn median_of(vals: &mut [f32]) -> f32 {
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = vals.len();
    if n % 2 == 1 {
        vals[n / 2]
    } else {
        0.5 * (vals[n / 2 - 1] + vals[n / 2])
    }
}

/// Model-level plan: per-layer plans + totals.
#[derive(Clone, Debug, Default)]
pub struct ModelPlan {
    pub layers: Vec<LayerPlan>,
    pub total_energy: f64,
    pub total_cycles: f64,
    pub peak_area: f64,
}

/// Plan a whole model given per-layer channel-energy slices.
pub fn plan_model(
    hw: &HardwareConfig,
    mode: AveragingMode,
    layers: &[(Vec<f64>, usize, f64)], // (e_per_channel, n_dot, macs_per_channel)
    quantized: bool,
) -> ModelPlan {
    let mut plan = ModelPlan::default();
    for (e, n_dot, mpc) in layers {
        let lp = plan_layer(hw, mode, e, *n_dot, *mpc, quantized);
        plan.total_energy += lp.energy;
        // Layers execute sequentially (layer l+1 consumes layer l).
        plan.total_cycles += lp.cycles;
        plan.peak_area = plan.peak_area.max(lp.area);
        plan.layers.push(lp);
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, default_cases, gens};
    use crate::util::rng::Rng;

    fn hw() -> HardwareConfig {
        HardwareConfig::crossbar()
    }

    #[test]
    fn time_averaging_trades_cycles() {
        let p = plan_layer(&hw(), AveragingMode::Time, &[4.0; 8], 27, 100.0, true);
        assert_eq!(p.cycles, 4.0);
        assert_eq!(p.area, 1.0); // one tile
        assert_eq!(p.energy, 4.0 * 100.0 * 8.0);
    }

    #[test]
    fn spatial_averaging_trades_area() {
        let p = plan_layer(&hw(), AveragingMode::Spatial, &[4.0; 8], 27, 100.0, true);
        assert_eq!(p.cycles, 1.0);
        assert_eq!(p.area, 4.0);
        assert_eq!(p.energy, 4.0 * 100.0 * 8.0);
    }

    #[test]
    fn per_row_uses_individual_k() {
        let e = vec![1.0, 9.0];
        let p = plan_layer(&hw(), AveragingMode::PerRowSpatial, &e, 27, 10.0, true);
        assert_eq!(p.k_per_channel, vec![1.0, 9.0]);
        assert_eq!(p.energy, 10.0 + 90.0);
        // area multiplier is the mean K
        assert_eq!(p.area, 5.0);
        assert_eq!(p.cycles, 1.0);
    }

    #[test]
    fn quantization_rounds_up() {
        let p = plan_layer(&hw(), AveragingMode::Time, &[2.3], 10, 1.0, true);
        assert_eq!(p.k_per_channel[0], 3.0);
        let pc = plan_layer(&hw(), AveragingMode::Time, &[2.3], 10, 1.0, false);
        assert!((pc.k_per_channel[0] - 2.3).abs() < 1e-12);
    }

    #[test]
    fn uniform_modes_use_max_channel_energy() {
        let e = vec![1.0, 7.0, 3.0];
        let p = plan_layer(&hw(), AveragingMode::Time, &e, 10, 1.0, false);
        assert_eq!(p.k_per_channel[0], 7.0);
    }

    #[test]
    fn model_totals_accumulate() {
        let layers = vec![
            (vec![2.0; 4], 27usize, 10.0f64),
            (vec![8.0; 2], 64, 5.0),
        ];
        let mp = plan_model(&hw(), AveragingMode::Time, &layers, false);
        assert_eq!(mp.layers.len(), 2);
        assert!((mp.total_energy - (2.0 * 10.0 * 4.0 + 8.0 * 5.0 * 2.0)).abs() < 1e-9);
        assert_eq!(mp.total_cycles, 10.0);
    }

    // ------------------------------------------- redundant tile coding

    fn tile(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.uniform_in(-0.5, 0.5) as f32).collect()
    }

    #[test]
    fn encode_decode_round_trips_with_zero_faults() {
        let w = tile(11, 64);
        for n in [1, 2, 3, 5] {
            let reps = encode_replicas(&w, n);
            assert_eq!(reps.len(), n);
            for mode in [DecodeMode::Median, DecodeMode::Average] {
                // Bit-exact: identical replicas decode to the original.
                assert_eq!(decode_replicas(&reps, mode), w, "n={n} {mode:?}");
            }
        }
    }

    #[test]
    fn median_masks_exactly_up_to_fault_budget() {
        let w = tile(23, 48);
        for n in [3usize, 4, 5, 7] {
            let budget = fault_budget(n);
            assert_eq!(budget, (n - 1) / 2);
            let mut reps = encode_replicas(&w, n);
            // Worst-case corruption: pull some replicas high, some low.
            for (k, rep) in reps.iter_mut().take(budget).enumerate() {
                let blow = if k % 2 == 0 { 1e6 } else { -1e6 };
                for v in rep.iter_mut() {
                    *v += blow;
                }
            }
            assert_eq!(
                decode_replicas(&reps, DecodeMode::Median),
                w,
                "n={n} masks {budget} faulty replicas exactly"
            );
        }
    }

    #[test]
    fn median_budget_is_tight_one_extra_fault_leaks() {
        let w = tile(31, 16);
        let n = 5;
        let k = fault_budget(n) + 1; // 3 of 5: clean values lose the vote
        let mut reps = encode_replicas(&w, n);
        for rep in reps.iter_mut().take(k) {
            for v in rep.iter_mut() {
                *v += 1e6;
            }
        }
        let decoded = decode_replicas(&reps, DecodeMode::Median);
        assert_ne!(decoded, w, "budget+1 faults must corrupt the decode");
    }

    #[test]
    fn average_decode_attenuates_but_does_not_mask() {
        let w = tile(47, 8);
        let mut reps = encode_replicas(&w, 4);
        for v in reps[0].iter_mut() {
            *v += 4.0;
        }
        let decoded = decode_replicas(&reps, DecodeMode::Average);
        for (d, orig) in decoded.iter().zip(&w) {
            assert!((d - orig - 1.0).abs() < 1e-5, "1/n of the fault leaks");
        }
    }

    #[test]
    fn prop_median_decode_masks_random_faults_within_budget() {
        check(
            "median masks <= budget faulty replicas",
            default_cases(200),
            |r: &mut Rng| {
                let n = 2 * gens::usize_in(r, 1, 3) + 1; // 3, 5, 7
                let len = gens::usize_in(r, 1, 32);
                let seed = r.next_u64();
                (n, len, seed)
            },
            |&(n, len, seed)| {
                let w = tile(seed, len);
                let mut reps = encode_replicas(&w, n);
                let mut r = Rng::new(seed ^ 0xDEAD);
                let k = r.below(fault_budget(n) as u64 + 1) as usize;
                for rep in reps.iter_mut().take(k) {
                    for v in rep.iter_mut() {
                        *v = r.uniform_in(-1e3, 1e3) as f32;
                    }
                }
                let got = decode_replicas(&reps, DecodeMode::Median);
                if got != w {
                    return Err(format!("n={n} k={k}: decode leaked"));
                }
                Ok(())
            },
        );
    }

    // ------------------------------------------------------- properties
    #[test]
    fn prop_quantized_energy_dominates_continuous() {
        check(
            "quantized >= continuous energy",
            default_cases(200),
            |r: &mut Rng| {
                let n = gens::usize_in(r, 1, 16);
                (gens::positive_vec(r, n, 20.0), gens::usize_in(r, 1, 512))
            },
            |(e, n_dot)| {
                let ef: Vec<f64> = e.iter().map(|&v| v as f64).collect();
                for mode in [
                    AveragingMode::Time,
                    AveragingMode::Spatial,
                    AveragingMode::PerRowSpatial,
                ] {
                    let q = plan_layer(&hw(), mode, &ef, *n_dot, 7.0, true);
                    let c = plan_layer(&hw(), mode, &ef, *n_dot, 7.0, false);
                    if q.energy + 1e-9 < c.energy {
                        return Err(format!(
                            "mode {mode:?}: quantized {} < continuous {}",
                            q.energy, c.energy
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_per_row_never_costs_more_than_uniform_spatial() {
        check(
            "per-row <= uniform spatial energy",
            default_cases(200),
            |r: &mut Rng| {
                let n = gens::usize_in(r, 1, 32);
                gens::positive_vec(r, n, 30.0)
            },
            |e| {
                let ef: Vec<f64> = e.iter().map(|&v| v as f64).collect();
                let row = plan_layer(&hw(), AveragingMode::PerRowSpatial, &ef, 64, 3.0, true);
                let uni = plan_layer(&hw(), AveragingMode::Spatial, &ef, 64, 3.0, true);
                if row.energy > uni.energy + 1e-9 {
                    return Err(format!("row {} > uniform {}", row.energy, uni.energy));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_energy_scales_linearly_in_k() {
        check(
            "energy linear in K (continuous)",
            default_cases(100),
            |r: &mut Rng| gens::f32_in(r, 0.1, 50.0) as f64,
            |&e| {
                let p1 = plan_layer(&hw(), AveragingMode::Time, &[e], 10, 2.0, false);
                let p2 = plan_layer(&hw(), AveragingMode::Time, &[2.0 * e], 10, 2.0, false);
                if (p2.energy - 2.0 * p1.energy).abs() > 1e-6 * p1.energy.max(1.0) {
                    return Err(format!("{} vs {}", p2.energy, 2.0 * p1.energy));
                }
                Ok(())
            },
        );
    }
}
