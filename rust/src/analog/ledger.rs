//! Energy/throughput accounting for the serving path.

use std::collections::BTreeMap;

/// Accumulates simulated analog costs across served requests.
#[derive(Clone, Debug, Default)]
pub struct EnergyLedger {
    /// Total MACs executed (per sample macs x samples).
    pub total_macs: f64,
    /// Total analog energy in base units (aJ for shot noise).
    pub total_energy: f64,
    /// Total simulated accelerator cycles.
    pub total_cycles: f64,
    /// Samples served.
    pub samples: u64,
    /// Per-model breakdown.
    pub per_model: BTreeMap<String, (f64, f64, u64)>, // (macs, energy, samples)
    /// Per-model, per-noise-site energy breakdown (site order): where a
    /// per-layer precision policy actually spends. Filled by backends
    /// that plan layer by layer (`plan_layer` per site); empty for
    /// backends that only report a model-level total.
    pub per_layer: BTreeMap<String, Vec<f64>>,
}

impl EnergyLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(
        &mut self,
        model: &str,
        samples: u64,
        macs_per_sample: f64,
        energy_per_sample: f64,
        cycles: f64,
    ) {
        let macs = macs_per_sample * samples as f64;
        let energy = energy_per_sample * samples as f64;
        self.total_macs += macs;
        self.total_energy += energy;
        self.total_cycles += cycles;
        self.samples += samples;
        let e = self.per_model.entry(model.to_string()).or_default();
        e.0 += macs;
        e.1 += energy;
        e.2 += samples;
    }

    /// Record one batch's per-noise-site energy split (per-sample
    /// values, site order) on top of the model-level totals already
    /// charged by [`EnergyLedger::record`] — the layer-resolved view a
    /// learned per-layer policy is audited against.
    pub fn record_layers(
        &mut self,
        model: &str,
        energy_per_layer: &[f64],
        samples: u64,
    ) {
        let acc = self.per_layer.entry(model.to_string()).or_default();
        if acc.len() < energy_per_layer.len() {
            acc.resize(energy_per_layer.len(), 0.0);
        }
        for (a, &e) in acc.iter_mut().zip(energy_per_layer) {
            *a += e * samples as f64;
        }
    }

    /// Fold another ledger into this one (fleet aggregation: the
    /// coordinator merges each device worker's private ledger into the
    /// fleet-wide view without any shared-lock traffic on the hot path).
    pub fn merge(&mut self, other: &EnergyLedger) {
        self.total_macs += other.total_macs;
        self.total_energy += other.total_energy;
        self.total_cycles += other.total_cycles;
        self.samples += other.samples;
        for (m, (macs, energy, samples)) in &other.per_model {
            let e = self.per_model.entry(m.clone()).or_default();
            e.0 += macs;
            e.1 += energy;
            e.2 += samples;
        }
        for (m, layers) in &other.per_layer {
            let acc = self.per_layer.entry(m.clone()).or_default();
            if acc.len() < layers.len() {
                acc.resize(layers.len(), 0.0);
            }
            for (a, &e) in acc.iter_mut().zip(layers) {
                *a += e;
            }
        }
    }

    /// Average energy/MAC across everything served so far.
    pub fn avg_energy_per_mac(&self) -> f64 {
        if self.total_macs == 0.0 {
            return 0.0;
        }
        self.total_energy / self.total_macs
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "ledger: {} samples, {:.3e} MACs, {:.3e} energy units, {:.4} units/MAC\n",
            self.samples,
            self.total_macs,
            self.total_energy,
            self.avg_energy_per_mac()
        );
        for (m, (macs, en, n)) in &self.per_model {
            s.push_str(&format!(
                "  {m}: {n} samples, {:.3e} MACs, {:.4} units/MAC\n",
                macs,
                if *macs > 0.0 { en / macs } else { 0.0 }
            ));
            if let Some(layers) = self.per_layer.get(m) {
                let total: f64 = layers.iter().sum();
                let shares: Vec<String> = layers
                    .iter()
                    .enumerate()
                    .map(|(i, &e)| {
                        format!(
                            "L{i}={:.1}%",
                            if total > 0.0 { 100.0 * e / total } else { 0.0 }
                        )
                    })
                    .collect();
                s.push_str(&format!(
                    "    per-layer energy: {}\n",
                    shares.join(" ")
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut l = EnergyLedger::new();
        l.record("m1", 10, 100.0, 250.0, 5.0);
        l.record("m1", 10, 100.0, 250.0, 5.0);
        l.record("m2", 5, 10.0, 100.0, 1.0);
        assert_eq!(l.samples, 25);
        assert_eq!(l.total_macs, 2050.0);
        assert_eq!(l.total_energy, 5500.0);
        let (macs, en, n) = l.per_model["m1"];
        assert_eq!((macs, en, n), (2000.0, 5000.0, 20));
        assert!((l.avg_energy_per_mac() - 5500.0 / 2050.0).abs() < 1e-12);
    }

    #[test]
    fn empty_ledger_is_zero() {
        assert_eq!(EnergyLedger::new().avg_energy_per_mac(), 0.0);
    }

    #[test]
    fn per_layer_entries_accumulate_and_merge() {
        let mut l = EnergyLedger::new();
        l.record("m1", 10, 100.0, 250.0, 5.0);
        l.record_layers("m1", &[20.0, 5.0], 10);
        l.record_layers("m1", &[20.0, 5.0], 2);
        // 20 * (10 + 2) and 5 * (10 + 2): per-sample splits scale by
        // the batch's sample count, exactly like `record`.
        assert_eq!(l.per_layer["m1"], vec![240.0, 60.0]);
        let mut other = EnergyLedger::new();
        other.record_layers("m1", &[1.0, 1.0, 1.0], 1);
        l.merge(&other);
        assert_eq!(l.per_layer["m1"], vec![241.0, 61.0, 1.0]);
        assert!(l.report().contains("per-layer energy"));
    }

    #[test]
    fn merge_equals_sequential_recording() {
        // Recording everything into one ledger and merging two
        // per-device ledgers must agree exactly.
        let mut all = EnergyLedger::new();
        all.record("m1", 10, 100.0, 250.0, 5.0);
        all.record("m2", 5, 10.0, 100.0, 1.0);

        let mut a = EnergyLedger::new();
        a.record("m1", 10, 100.0, 250.0, 5.0);
        let mut b = EnergyLedger::new();
        b.record("m2", 5, 10.0, 100.0, 1.0);
        let mut merged = EnergyLedger::new();
        merged.merge(&a);
        merged.merge(&b);

        assert_eq!(merged.samples, all.samples);
        assert_eq!(merged.total_macs, all.total_macs);
        assert_eq!(merged.total_energy, all.total_energy);
        assert_eq!(merged.total_cycles, all.total_cycles);
        assert_eq!(merged.per_model, all.per_model);
    }
}
