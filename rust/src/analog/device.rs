//! Device models: resistive crossbar and homodyne optical multiplier.

/// Physical/architectural constants of one analog matrix multiplier.
#[derive(Clone, Debug)]
pub struct HardwareConfig {
    /// Crossbar/detector array rows (dot-product length capacity).
    pub array_rows: usize,
    /// Array columns (parallel output channels).
    pub array_cols: usize,
    /// Clock period in nanoseconds (one MVM issue per cycle).
    pub cycle_ns: f64,
    /// Energy/MAC at unit redundancy (E = 1), in attojoules. For the
    /// shot-noise-limited homodyne multiplier this is the *optical*
    /// energy; E is then an absolute quantity in aJ (paper Sec. IV).
    pub base_energy_aj: f64,
    /// Device kind (affects which noise family dominates).
    pub model: DeviceModel,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceModel {
    /// Resistive crossbar (flash/memristor/PCM): thermal + weight noise.
    Crossbar,
    /// Homodyne photoelectric multiplier: shot-noise limited.
    Homodyne,
    /// Broadcast-and-weight photonics: thermal-noise limited.
    BroadcastWeight,
}

impl DeviceModel {
    /// Short stable label for fleet reports and logs.
    pub fn label(&self) -> &'static str {
        match self {
            DeviceModel::Crossbar => "crossbar",
            DeviceModel::Homodyne => "homodyne",
            DeviceModel::BroadcastWeight => "broadcast",
        }
    }
}

impl HardwareConfig {
    /// Defaults mirroring the paper's reference points.
    pub fn crossbar() -> Self {
        HardwareConfig {
            array_rows: 256,
            array_cols: 256,
            cycle_ns: 10.0,
            base_energy_aj: 1.0, // relative units for thermal/weight noise
            model: DeviceModel::Crossbar,
        }
    }

    pub fn homodyne() -> Self {
        HardwareConfig {
            array_rows: 256,
            array_cols: 256,
            cycle_ns: 1.0,
            base_energy_aj: 1.0, // E is absolute aJ for shot noise
            model: DeviceModel::Homodyne,
        }
    }

    pub fn broadcast_weight() -> Self {
        HardwareConfig {
            array_rows: 256,
            array_cols: 256,
            cycle_ns: 2.0,
            base_energy_aj: 1.0, // relative units for thermal noise
            model: DeviceModel::BroadcastWeight,
        }
    }

    /// Natural noise family of this device.
    pub fn default_noise(&self) -> &'static str {
        match self.model {
            DeviceModel::Crossbar => "weight",
            DeviceModel::Homodyne => "shot",
            DeviceModel::BroadcastWeight => "thermal",
        }
    }

    /// Tiles needed to map an (n_dot x n_channels) weight matrix.
    pub fn tiles_for(&self, n_dot: usize, n_channels: usize) -> usize {
        n_dot.div_ceil(self.array_rows) * n_channels.div_ceil(self.array_cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiling() {
        let hw = HardwareConfig::crossbar();
        assert_eq!(hw.tiles_for(256, 256), 1);
        assert_eq!(hw.tiles_for(257, 256), 2);
        assert_eq!(hw.tiles_for(512, 512), 4);
        assert_eq!(hw.tiles_for(1, 1), 1);
    }

    #[test]
    fn default_noise_per_device() {
        assert_eq!(HardwareConfig::crossbar().default_noise(), "weight");
        assert_eq!(HardwareConfig::homodyne().default_noise(), "shot");
        assert_eq!(
            HardwareConfig::broadcast_weight().default_noise(),
            "thermal"
        );
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(DeviceModel::Crossbar.label(), "crossbar");
        assert_eq!(DeviceModel::Homodyne.label(), "homodyne");
        assert_eq!(DeviceModel::BroadcastWeight.label(), "broadcast");
    }
}
