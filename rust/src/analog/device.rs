//! Device models: resistive crossbar and homodyne optical multiplier.

/// Physical/architectural constants of one analog matrix multiplier.
#[derive(Clone, Debug)]
pub struct HardwareConfig {
    /// Crossbar/detector array rows (dot-product length capacity).
    pub array_rows: usize,
    /// Array columns (parallel output channels).
    pub array_cols: usize,
    /// Clock period in nanoseconds (one MVM issue per cycle).
    pub cycle_ns: f64,
    /// Energy/MAC at unit redundancy (E = 1), in attojoules. For the
    /// shot-noise-limited homodyne multiplier this is the *optical*
    /// energy; E is then an absolute quantity in aJ (paper Sec. IV).
    pub base_energy_aj: f64,
    /// Device kind (affects which noise family dominates).
    pub model: DeviceModel,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceModel {
    /// Resistive crossbar (flash/memristor/PCM): thermal + weight noise.
    Crossbar,
    /// Homodyne photoelectric multiplier: shot-noise limited.
    Homodyne,
    /// Broadcast-and-weight photonics: thermal-noise limited.
    BroadcastWeight,
}

impl DeviceModel {
    /// Short stable label for fleet reports and logs.
    pub fn label(&self) -> &'static str {
        match self {
            DeviceModel::Crossbar => "crossbar",
            DeviceModel::Homodyne => "homodyne",
            DeviceModel::BroadcastWeight => "broadcast",
        }
    }
}

/// The noise family that dominates an analog matrix multiplier — which
/// physical mechanism the native execution backend samples from (and
/// which artifact family the PJRT path selects). Replaces the old
/// string-typed `"shot"`/`"thermal"`/`"weight"` convention.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NoiseKind {
    /// Photon shot noise (homodyne optical multiplier): variance set by
    /// the detected photon count, i.e. by optical energy/MAC in aJ.
    Shot,
    /// Thermal/detector noise (broadcast-and-weight photonics), signal-
    /// independent additive noise on each output channel.
    Thermal,
    /// Weight read noise (resistive crossbar): per-weight conductance
    /// error; crossbars carry thermal noise on top (paper Sec. II-C).
    Weight,
}

impl NoiseKind {
    /// Stable lowercase name, matching the artifact-tag convention
    /// (`"{name}.fwd"`, `"{name}.grad"`) and the energy-table JSON.
    pub fn as_str(&self) -> &'static str {
        match self {
            NoiseKind::Shot => "shot",
            NoiseKind::Thermal => "thermal",
            NoiseKind::Weight => "weight",
        }
    }

    /// Parse the artifact/table convention back into the enum.
    pub fn parse(s: &str) -> Option<NoiseKind> {
        match s {
            "shot" => Some(NoiseKind::Shot),
            "thermal" => Some(NoiseKind::Thermal),
            "weight" => Some(NoiseKind::Weight),
            _ => None,
        }
    }
}

impl std::fmt::Display for NoiseKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl HardwareConfig {
    /// Defaults mirroring the paper's reference points.
    pub fn crossbar() -> Self {
        HardwareConfig {
            array_rows: 256,
            array_cols: 256,
            cycle_ns: 10.0,
            base_energy_aj: 1.0, // relative units for thermal/weight noise
            model: DeviceModel::Crossbar,
        }
    }

    pub fn homodyne() -> Self {
        HardwareConfig {
            array_rows: 256,
            array_cols: 256,
            cycle_ns: 1.0,
            base_energy_aj: 1.0, // E is absolute aJ for shot noise
            model: DeviceModel::Homodyne,
        }
    }

    pub fn broadcast_weight() -> Self {
        HardwareConfig {
            array_rows: 256,
            array_cols: 256,
            cycle_ns: 2.0,
            base_energy_aj: 1.0, // relative units for thermal noise
            model: DeviceModel::BroadcastWeight,
        }
    }

    /// Natural (dominant) noise family of this device.
    pub fn default_noise(&self) -> NoiseKind {
        match self.model {
            DeviceModel::Crossbar => NoiseKind::Weight,
            DeviceModel::Homodyne => NoiseKind::Shot,
            DeviceModel::BroadcastWeight => NoiseKind::Thermal,
        }
    }

    /// Tiles needed to map an (n_dot x n_channels) weight matrix.
    pub fn tiles_for(&self, n_dot: usize, n_channels: usize) -> usize {
        n_dot.div_ceil(self.array_rows) * n_channels.div_ceil(self.array_cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiling() {
        let hw = HardwareConfig::crossbar();
        assert_eq!(hw.tiles_for(256, 256), 1);
        assert_eq!(hw.tiles_for(257, 256), 2);
        assert_eq!(hw.tiles_for(512, 512), 4);
        assert_eq!(hw.tiles_for(1, 1), 1);
    }

    #[test]
    fn default_noise_per_device() {
        assert_eq!(HardwareConfig::crossbar().default_noise(), NoiseKind::Weight);
        assert_eq!(HardwareConfig::homodyne().default_noise(), NoiseKind::Shot);
        assert_eq!(
            HardwareConfig::broadcast_weight().default_noise(),
            NoiseKind::Thermal
        );
    }

    #[test]
    fn noise_kind_roundtrips_the_string_convention() {
        for k in [NoiseKind::Shot, NoiseKind::Thermal, NoiseKind::Weight] {
            assert_eq!(NoiseKind::parse(k.as_str()), Some(k));
            assert_eq!(format!("{k}"), k.as_str());
        }
        assert_eq!(NoiseKind::parse("quantum"), None);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(DeviceModel::Crossbar.label(), "crossbar");
        assert_eq!(DeviceModel::Homodyne.label(), "homodyne");
        assert_eq!(DeviceModel::BroadcastWeight.label(), "broadcast");
    }
}
