//! Analog accelerator model (paper Secs. II-C and IV).
//!
//! The PJRT artifacts compute the *numerics* of noisy inference (the noise
//! already folded to `sigma/sqrt(E)`); this module models the
//! *architecture* that realizes a given energy/MAC: how much redundant
//! coding (K repeats in time or space, Fig. 3) each layer needs, and what
//! that costs in cycles, devices, area and joules.
//!
//! Three pieces:
//!
//! - [`device`] — physical constants of one analog matrix multiplier
//!   ([`HardwareConfig`]); a fleet may mix several (see
//!   `coordinator::fleet`).
//! - [`redundancy`] — the Fig.-3 planner: energy request -> repetition
//!   factor K -> cycles/area/energy ([`plan_layer`], [`plan_model`]) —
//!   plus the fault-masking replica codec ([`encode_replicas`],
//!   [`decode_replicas`]) the native path uses to survive stuck cells.
//! - [`ledger`] — serving-time accounting ([`EnergyLedger`]); each
//!   fleet device keeps its own and the coordinator merges them.

pub mod device;
pub mod ledger;
pub mod redundancy;

pub use device::{DeviceModel, HardwareConfig, NoiseKind};
pub use ledger::EnergyLedger;
pub use redundancy::{
    decode_replica_buffers_into, decode_replicas, decode_replicas_into,
    encode_replicas, fault_budget,
    plan_layer, plan_model, AveragingMode, DecodeMode, LayerPlan,
};
