//! Quantization math + the paper's noise-bits theory (Sec. III).

pub mod noise_bits;

/// Affine uniform fake-quantization (paper Eq. 2): map `x` onto `levels`
/// uniformly spaced values spanning [lo, hi], clipping outside.
pub fn fake_quant(x: f32, lo: f32, hi: f32, levels: u32) -> f32 {
    debug_assert!(levels >= 2);
    let delta = (hi - lo) / (levels - 1) as f32;
    if delta <= 0.0 {
        return lo;
    }
    let q = ((x.clamp(lo, hi) - lo) / delta).round();
    lo + q * delta
}

/// Quantization-noise variance for B bits over a range (paper Eq. 6):
/// Var = ((hi-lo)/(2^B - 1))^2 / 12. B may be fractional.
pub fn quant_noise_var(range: f64, bits: f64) -> f64 {
    let delta = range / (2f64.powf(bits) - 1.0);
    delta * delta / 12.0
}

/// Levels for a fractional bit count (paper footnote 1: B bits ->
/// ceil(2^B) levels, e.g. 4.644 bits -> 25 levels).
pub fn levels_for_bits(bits: f64) -> u32 {
    // Small epsilon so B = log2(n) maps back to exactly n levels.
    ((2f64.powf(bits) - 1e-6).ceil() as u32).max(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_quant_endpoints_exact() {
        assert_eq!(fake_quant(-1.0, -1.0, 1.0, 256), -1.0);
        assert_eq!(fake_quant(1.0, -1.0, 1.0, 256), 1.0);
        assert_eq!(fake_quant(5.0, -1.0, 1.0, 256), 1.0); // clip
        assert_eq!(fake_quant(-5.0, -1.0, 1.0, 256), -1.0);
    }

    #[test]
    fn fake_quant_grid() {
        // 3 levels over [0, 1]: {0, 0.5, 1}
        assert_eq!(fake_quant(0.2, 0.0, 1.0, 3), 0.0);
        assert_eq!(fake_quant(0.3, 0.0, 1.0, 3), 0.5);
        assert_eq!(fake_quant(0.8, 0.0, 1.0, 3), 1.0);
    }

    #[test]
    fn quant_error_bounded_by_half_delta() {
        let (lo, hi, levels) = (-2.0f32, 3.0f32, 256u32);
        let delta = (hi - lo) / (levels - 1) as f32;
        for i in 0..1000 {
            let x = lo + (hi - lo) * (i as f32 / 999.0);
            let err = (fake_quant(x, lo, hi, levels) - x).abs();
            assert!(err <= delta / 2.0 + 1e-6);
        }
    }

    #[test]
    fn fractional_levels_match_paper_footnote() {
        // "quantization over 25 uniformly spaced bins requires 4.644 bits"
        assert_eq!(levels_for_bits(25f64.log2()), 25);
        assert_eq!(levels_for_bits(8.0), 256);
        assert_eq!(levels_for_bits(1.0), 2);
    }

    #[test]
    fn quant_var_matches_uniform_model() {
        // 8 bits over range 1: delta = 1/255, var = delta^2/12.
        let v = quant_noise_var(1.0, 8.0);
        let delta = 1.0 / 255.0f64;
        assert!((v - delta * delta / 12.0).abs() < 1e-18);
    }
}
