//! Noise-equivalent bit precision (paper Sec. III, Eq. 6-8).
//!
//! `B_eps` for a layer is the (fractional) bit count at which uniform
//! quantization noise over the layer's *output* range has the same
//! variance as the analog noise. For thermal noise (Eq. 3/9) this has the
//! closed form of Eq. 8; for weight noise we evaluate the variance from
//! the meta ranges; shot noise is signal-dependent and handled by the
//! empirical path (Table I/III use thermal, as in the paper).

use crate::runtime::artifact::{ModelMeta, SiteMeta};

/// Noise bits from an analog noise variance and the layer output range
/// (paper Eq. 7): B = log2(range / sqrt(12 Var) + 1).
pub fn bits_from_var(out_range: f64, var: f64) -> f64 {
    if var <= 0.0 {
        return f64::INFINITY;
    }
    (out_range / (12.0 * var).sqrt() + 1.0).log2()
}

/// Thermal-noise variance of one site's output at energy/MAC `e`
/// (paper Eq. 9): Var = N * (Wrange * Xrange * sigma_t)^2 / e.
pub fn thermal_var(site: &SiteMeta, sigma_t: f64, e: f64, clip: bool) -> f64 {
    let w_range = site.w_hi_layer - site.w_lo_layer;
    let x_range = if clip {
        site.in_hi_clip - site.in_lo_clip
    } else {
        site.in_hi - site.in_lo
    };
    let std = (site.n_dot as f64).sqrt() * w_range * x_range * sigma_t / e.sqrt();
    std * std
}

/// Weight-read-noise variance proxy of one site's output at energy `e`
/// (paper Eq. 10): per-weight std (Wrange * sigma_w / sqrt(e)); the dot
/// product of N noisy weights with inputs of RMS ~ Xrange/sqrt(12) gives
/// Var ~ N * (Wrange * sigma_w)^2/e * E[x^2].
pub fn weight_var(site: &SiteMeta, sigma_w: f64, e: f64) -> f64 {
    let w_range = site.w_hi_layer - site.w_lo_layer;
    let x_range = site.in_hi - site.in_lo;
    // E[x^2] for a uniform distribution over the input range (paper's
    // uniform-signal approximation in Sec. III).
    let ex2 = x_range * x_range / 12.0;
    (site.n_dot as f64) * (w_range * sigma_w).powi(2) / e * ex2
}

/// Thermal noise bits of one site (paper Eq. 8).
pub fn thermal_bits(site: &SiteMeta, sigma_t: f64, e: f64, clip: bool) -> f64 {
    let out_range = if clip {
        site.out_hi_clip - site.out_lo_clip
    } else {
        site.out_hi - site.out_lo
    };
    bits_from_var(out_range, thermal_var(site, sigma_t, e, clip))
}

/// Per-noise-site thermal noise bits for a whole model at per-layer
/// energies `e_layers` (len = number of noise sites). Returns (site
/// index, bits) pairs in site order.
pub fn model_thermal_bits(
    meta: &ModelMeta,
    sigma_t: f64,
    e_layers: &[f64],
    clip: bool,
) -> Vec<(usize, f64)> {
    meta.noise_sites()
        .zip(e_layers.iter())
        .map(|((i, s), &e)| (i, thermal_bits(s, sigma_t, e, clip)))
        .collect()
}

/// Average bits across noise sites (paper Tables I/III report this).
pub fn average_bits(bits: &[(usize, f64)]) -> f64 {
    let finite: Vec<f64> = bits
        .iter()
        .map(|&(_, b)| b)
        .filter(|b| b.is_finite())
        .collect();
    finite.iter().sum::<f64>() / finite.len().max(1) as f64
}

/// Full bit vector (one entry per site, NaN for non-noise sites) for the
/// lowbit artifact input.
pub fn bits_vector_for_lowbit(
    meta: &ModelMeta,
    site_bits: &[(usize, f64)],
    default_bits: f64,
) -> Vec<f32> {
    let mut v = vec![default_bits as f32; meta.n_sites];
    for &(i, b) in site_bits {
        // Cap at 16 bits: above that the quantization grid underflows f32
        // and "effectively fp" is what the paper's Table I rows show.
        v[i] = b.min(16.0) as f32;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn site() -> SiteMeta {
        SiteMeta {
            name: "s".into(),
            kind: "conv".into(),
            n_dot: 27,
            n_channels: 8,
            macs_per_channel: 100.0,
            e_offset: 0,
            in_lo: -1.0,
            in_hi: 1.0,
            in_lo_clip: -0.9,
            in_hi_clip: 0.9,
            out_lo: -2.0,
            out_hi: 2.0,
            out_lo_clip: -1.8,
            out_hi_clip: 1.8,
            w_lo_layer: -0.5,
            w_hi_layer: 0.5,
            w_lo: vec![],
            w_hi: vec![],
        }
    }

    #[test]
    fn eq8_closed_form_matches_composition() {
        // Eq. 8 is bits_from_var(out_range, thermal_var): check the
        // explicit formula.
        let s = site();
        let (sigma, e) = (0.01, 4.0);
        let b = thermal_bits(&s, sigma, e, false);
        let denom =
            sigma / e.sqrt() * 1.0 * 2.0 * (12.0f64 * 27.0).sqrt();
        let expect = (4.0 / denom + 1.0).log2();
        assert!((b - expect).abs() < 1e-12, "{b} vs {expect}");
    }

    #[test]
    fn more_energy_more_bits() {
        let s = site();
        let b1 = thermal_bits(&s, 0.01, 1.0, false);
        let b4 = thermal_bits(&s, 0.01, 4.0, false);
        // 4x energy halves the noise std -> ~+1 bit in the high-SNR regime.
        assert!(b4 > b1);
        assert!((b4 - b1 - 1.0).abs() < 0.1, "b1={b1} b4={b4}");
    }

    #[test]
    fn zero_noise_is_infinite_bits() {
        assert!(bits_from_var(1.0, 0.0).is_infinite());
    }

    #[test]
    fn clip_ranges_reduce_noise() {
        let s = site();
        // Clipped input range is smaller -> smaller thermal noise var.
        assert!(thermal_var(&s, 0.01, 1.0, true) < thermal_var(&s, 0.01, 1.0, false));
    }

    #[test]
    fn average_ignores_infinities() {
        let b = vec![(0, 4.0), (1, f64::INFINITY), (2, 6.0)];
        assert_eq!(average_bits(&b), 5.0);
    }
}
