//! Energy-allocation optimization (paper Sec. V) and the minimum-energy
//! binary search (Sec. VI-A).

pub mod adam;
pub mod search;
pub mod trainer;

pub use adam::Adam;
pub use search::{binary_search_emax, SearchCfg, SearchResult};
pub use trainer::{train_energy, Granularity, TrainCfg, TrainResult};
