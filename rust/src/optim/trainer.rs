//! Eq.-14 energy-allocation training loop (paper Sec. V).
//!
//! Runs Adam over log-E, calling the AOT grad artifact for the
//! Monte-Carlo value-and-grad of
//!
//!   L(E) = NLL(y | x, xi; theta, E)
//!        + lambda * max(log sum_l E_l n_mac_l - log E_max, 0)
//!
//! Network weights theta stay frozen (they live in params.bin); only E
//! moves. Per-layer granularity ties channels within a site: the full
//! per-channel gradient is summed per site (chain rule of the tie).

use anyhow::Result;

use crate::data::Dataset;
use crate::ops::ModelOps;
use crate::optim::adam::Adam;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    PerLayer,
    PerChannel,
}

#[derive(Clone, Debug)]
pub struct TrainCfg {
    /// Artifact tag prefix: "thermal", "weight", "shot",
    /// "thermal_noclip", "shot_photonq".
    pub noise_tag: String,
    pub granularity: Granularity,
    /// Adam learning rate on log-E (paper: 0.01).
    pub lr: f32,
    /// Penalty weight lambda (paper: 2 for shot, 8 for thermal/weight).
    pub lam: f32,
    /// Energy budget as average energy/MAC (converted to log total).
    pub target_avg_e: f64,
    /// Initial energy/MAC for all layers.
    pub init_e: f64,
    pub steps: usize,
    pub seed: u32,
}

impl TrainCfg {
    pub fn paper_lambda(noise: &str) -> f32 {
        if noise.starts_with("shot") {
            2.0
        } else {
            8.0
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainResult {
    /// Final per-channel energy vector.
    pub e: Vec<f32>,
    /// Per-layer mean energies (noise sites, in order).
    pub e_per_layer: Vec<f64>,
    /// Average energy/MAC achieved.
    pub avg_e: f64,
    pub loss_history: Vec<f32>,
    pub final_acc: f32,
}

pub fn train_energy(
    ops: &ModelOps,
    data: &Dataset,
    cfg: &TrainCfg,
) -> Result<TrainResult> {
    let meta = &ops.bundle.meta;
    let grad_tag = format!("{}.grad", cfg.noise_tag);
    let n_layers = meta.noise_sites().count();
    let b = meta.batch;
    let n_batches = data.n_batches(b).max(1);

    // Trainable vector: per-layer or per-channel log-E.
    let n_train = match cfg.granularity {
        Granularity::PerLayer => n_layers,
        Granularity::PerChannel => meta.e_len,
    };
    let mut loge = vec![(cfg.init_e as f32).ln(); n_train];
    let mut opt = Adam::new(n_train, cfg.lr);

    // Budget: log of total energy at the target average.
    let log_emax = (cfg.target_avg_e * meta.total_macs).ln() as f32;

    let mut history = Vec::with_capacity(cfg.steps);
    let mut acc = 0.0f32;
    for step in 0..cfg.steps {
        let bi = step % n_batches;
        let x = data.batch_x(bi, b);
        let y = data.batch_y(bi, b);
        let loge_full = expand(meta, cfg.granularity, &loge);
        let out = ops.grad_step(
            &grad_tag,
            &x,
            y,
            cfg.seed.wrapping_add(step as u32),
            &loge_full,
            cfg.lam,
            log_emax,
        )?;
        let g = compress(meta, cfg.granularity, &out.grad_loge);
        opt.step(&mut loge, &g);
        history.push(out.loss);
        acc = out.acc;
    }

    let e_full: Vec<f32> = expand(meta, cfg.granularity, &loge)
        .iter()
        .map(|l| l.exp())
        .collect();
    let avg_e = meta.avg_energy_per_mac(&e_full);
    let e_per_layer = meta.per_layer_mean(&e_full);
    Ok(TrainResult {
        e: e_full,
        e_per_layer,
        avg_e,
        loss_history: history,
        final_acc: acc,
    })
}

/// Expand the trainable vector into the artifact's per-channel layout.
fn expand(
    meta: &crate::runtime::artifact::ModelMeta,
    g: Granularity,
    loge: &[f32],
) -> Vec<f32> {
    match g {
        Granularity::PerChannel => loge.to_vec(),
        Granularity::PerLayer => {
            let mut full = vec![0.0f32; meta.e_len];
            for (li, (_, s)) in meta.noise_sites().enumerate() {
                for c in 0..s.n_channels {
                    full[s.e_offset + c] = loge[li];
                }
            }
            full
        }
    }
}

/// Compress a per-channel gradient back to the trainable layout.
fn compress(
    meta: &crate::runtime::artifact::ModelMeta,
    g: Granularity,
    grad_full: &[f32],
) -> Vec<f32> {
    match g {
        Granularity::PerChannel => grad_full.to_vec(),
        Granularity::PerLayer => meta
            .noise_sites()
            .map(|(_, s)| {
                grad_full[s.e_offset..s.e_offset + s.n_channels]
                    .iter()
                    .sum::<f32>()
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ModelMeta;

    fn meta() -> ModelMeta {
        let text = r#"{
          "name": "m", "kind": "vision", "batch": 32, "params_len": 10,
          "e_len": 5, "n_sites": 2, "total_macs_per_sample": 48.0,
          "sigma_thermal": 0.01, "sigma_weight": 0.1,
          "photons_per_aj": 7.8125, "act_bits": 8,
          "baselines": {"fp_acc": 0.9, "quant_acc": null},
          "artifacts": {},
          "sites": [
            {"name": "a", "kind": "conv", "n_dot": 27, "n_channels": 4,
             "macs_per_channel": 10.0, "e_offset": 0,
             "in_lo": -1, "in_hi": 1, "in_lo_clip": -1, "in_hi_clip": 1,
             "out_lo": 0, "out_hi": 2, "out_lo_clip": 0, "out_hi_clip": 2,
             "w_lo_layer": -0.5, "w_hi_layer": 0.5, "w_lo": [], "w_hi": []},
            {"name": "b", "kind": "dense", "n_dot": 8, "n_channels": 1,
             "macs_per_channel": 8.0, "e_offset": 4,
             "in_lo": 0, "in_hi": 1, "in_lo_clip": 0, "in_hi_clip": 1,
             "out_lo": -3, "out_hi": 3, "out_lo_clip": -3, "out_hi_clip": 3,
             "w_lo_layer": -1, "w_hi_layer": 1, "w_lo": [], "w_hi": []}
          ]
        }"#;
        ModelMeta::parse(text).unwrap()
    }

    #[test]
    fn expand_compress_roundtrip_per_layer() {
        let m = meta();
        let loge = vec![1.0f32, 3.0];
        let full = expand(&m, Granularity::PerLayer, &loge);
        assert_eq!(full, vec![1.0, 1.0, 1.0, 1.0, 3.0]);
        let grad = vec![0.5f32, 0.5, 0.5, 0.5, 2.0];
        let c = compress(&m, Granularity::PerLayer, &grad);
        assert_eq!(c, vec![2.0, 2.0]);
    }

    #[test]
    fn per_channel_is_identity() {
        let m = meta();
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(expand(&m, Granularity::PerChannel, &v), v);
        assert_eq!(compress(&m, Granularity::PerChannel, &v), v);
    }

    #[test]
    fn paper_lambdas() {
        assert_eq!(TrainCfg::paper_lambda("shot"), 2.0);
        assert_eq!(TrainCfg::paper_lambda("shot_photonq"), 2.0);
        assert_eq!(TrainCfg::paper_lambda("thermal"), 8.0);
        assert_eq!(TrainCfg::paper_lambda("weight"), 8.0);
    }
}
