//! Eq.-14 energy-allocation training loop (paper Sec. V).
//!
//! Runs Adam over log-E, calling [`ModelOps::grad_step`] — the AOT grad
//! artifact or the native Monte-Carlo estimator — for the value-and-grad
//! of
//!
//!   L(E) = NLL(y | x, xi; theta, E)
//!        + lambda * max(log sum_l E_l n_mac_l - log E_max, 0)
//!
//! Network weights theta stay frozen (params.bin / the name-seeded
//! native weights); only E moves. Per-layer granularity ties channels
//! within a site: the full per-channel gradient is summed per site
//! (chain rule of the tie).

use anyhow::Result;

use crate::data::Dataset;
use crate::ops::ModelOps;
use crate::optim::adam::Adam;
use crate::runtime::artifact::ModelMeta;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    PerLayer,
    PerChannel,
}

#[derive(Clone, Debug)]
pub struct TrainCfg {
    /// Artifact tag prefix: "thermal", "weight", "shot",
    /// "thermal_noclip", "shot_photonq".
    pub noise_tag: String,
    pub granularity: Granularity,
    /// Adam learning rate on log-E (paper: 0.01).
    pub lr: f32,
    /// Penalty weight lambda (paper: 2 for shot, 8 for thermal/weight).
    pub lam: f32,
    /// Energy budget as average energy/MAC (converted to log total).
    pub target_avg_e: f64,
    /// Initial energy/MAC for all layers.
    pub init_e: f64,
    pub steps: usize,
    pub seed: u32,
}

impl TrainCfg {
    pub fn paper_lambda(noise: &str) -> f32 {
        if noise.starts_with("shot") {
            2.0
        } else {
            8.0
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainResult {
    /// Final per-channel energy vector.
    pub e: Vec<f32>,
    /// Per-layer mean energies (noise sites, in order).
    pub e_per_layer: Vec<f64>,
    /// Average energy/MAC achieved.
    pub avg_e: f64,
    pub loss_history: Vec<f32>,
    pub final_acc: f32,
}

impl TrainResult {
    /// Noise-site indices ranked most-error-sensitive first. The Eq.-14
    /// trainer spends its budget where noise hurts accuracy most, so
    /// the learned per-layer energy *is* the sensitivity signal: a
    /// layer allocated more energy/MAC needs its GEMM protected first.
    /// This is the ranking a hybrid split consumes when deciding which
    /// layers to run on exact digital tiles
    /// (`crate::backend::hybrid_split` applies the same ordering to a
    /// scheduled e-vector). Ties keep site order, so the ranking is
    /// deterministic.
    pub fn sensitivity_ranking(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.e_per_layer.len()).collect();
        idx.sort_by(|&a, &b| {
            self.e_per_layer[b]
                .partial_cmp(&self.e_per_layer[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx
    }
}

pub fn train_energy(
    ops: &dyn ModelOps,
    data: &Dataset,
    cfg: &TrainCfg,
) -> Result<TrainResult> {
    let meta = ops.meta();
    let grad_tag = format!("{}.grad", cfg.noise_tag);
    let n_layers = meta.noise_sites().count();
    let b = meta.batch;
    let n_batches = data.n_batches(b).max(1);

    // Trainable vector: per-layer or per-channel log-E.
    let n_train = match cfg.granularity {
        Granularity::PerLayer => n_layers,
        Granularity::PerChannel => meta.e_len,
    };
    let mut loge = vec![(cfg.init_e as f32).ln(); n_train];
    let mut opt = Adam::new(n_train, cfg.lr);

    // Budget: log of total energy at the target average.
    let log_emax = (cfg.target_avg_e * meta.total_macs).ln() as f32;

    let mut history = Vec::with_capacity(cfg.steps);
    let mut acc = 0.0f32;
    for step in 0..cfg.steps {
        let bi = step % n_batches;
        let x = data.batch_x(bi, b);
        let y = data.batch_y(bi, b);
        let loge_full = expand(meta, cfg.granularity, &loge);
        let out = ops.grad_step(
            &grad_tag,
            &x,
            y,
            cfg.seed.wrapping_add(step as u32),
            &loge_full,
            cfg.lam,
            log_emax,
        )?;
        let g = compress(meta, cfg.granularity, &out.grad_loge);
        opt.step(&mut loge, &g);
        history.push(out.loss);
        acc = out.acc;
    }

    let e_full: Vec<f32> = expand(meta, cfg.granularity, &loge)
        .iter()
        .map(|l| l.exp())
        .collect();
    let avg_e = meta.avg_energy_per_mac(&e_full);
    let e_per_layer = meta.per_layer_mean(&e_full);
    Ok(TrainResult {
        e: e_full,
        e_per_layer,
        avg_e,
        loss_history: history,
        final_acc: acc,
    })
}

/// Eq.-14 budget barrier and its exact gradient w.r.t. log-E:
///
///   P(E)        = lambda * max(log sum_c E_c n_mac_c - log E_max, 0)
///   dP/dlogE_c  = lambda * E_c n_mac_c / sum_j E_j n_mac_j   (if active)
///
/// The penalty activates iff the total energy exceeds the budget; its
/// gradient is strictly positive on every channel that costs MACs, so
/// a gradient-descent step on log-E (`param -= lr * grad`) pushes
/// energies *down*. The grad artifacts differentiate this term with AD;
/// [`crate::ops::NativeOps`] calls this closed form directly.
pub fn eq14_penalty(
    meta: &ModelMeta,
    e: &[f32],
    lam: f32,
    log_emax: f32,
) -> (f32, Vec<f32>) {
    let mut total = 0.0f64; // sum_c E_c * macs_c
    for s in &meta.sites {
        for c in 0..s.n_channels {
            total += e[s.e_offset + c] as f64 * s.macs_per_channel;
        }
    }
    let mut grad = vec![0.0f32; e.len()];
    let excess = total.max(f64::MIN_POSITIVE).ln() as f32 - log_emax;
    if excess <= 0.0 {
        return (0.0, grad);
    }
    for s in &meta.sites {
        for c in 0..s.n_channels {
            grad[s.e_offset + c] =
                lam * (e[s.e_offset + c] as f64 * s.macs_per_channel
                    / total) as f32;
        }
    }
    (lam * excess, grad)
}

/// Expand the trainable vector into the artifact's per-channel layout.
fn expand(
    meta: &crate::runtime::artifact::ModelMeta,
    g: Granularity,
    loge: &[f32],
) -> Vec<f32> {
    match g {
        Granularity::PerChannel => loge.to_vec(),
        Granularity::PerLayer => {
            let mut full = vec![0.0f32; meta.e_len];
            for (li, (_, s)) in meta.noise_sites().enumerate() {
                for c in 0..s.n_channels {
                    full[s.e_offset + c] = loge[li];
                }
            }
            full
        }
    }
}

/// Compress a per-channel gradient back to the trainable layout.
fn compress(
    meta: &crate::runtime::artifact::ModelMeta,
    g: Granularity,
    grad_full: &[f32],
) -> Vec<f32> {
    match g {
        Granularity::PerChannel => grad_full.to_vec(),
        Granularity::PerLayer => meta
            .noise_sites()
            .map(|(_, s)| {
                grad_full[s.e_offset..s.e_offset + s.n_channels]
                    .iter()
                    .sum::<f32>()
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ModelMeta;

    fn meta() -> ModelMeta {
        let text = r#"{
          "name": "m", "kind": "vision", "batch": 32, "params_len": 10,
          "e_len": 5, "n_sites": 2, "total_macs_per_sample": 48.0,
          "sigma_thermal": 0.01, "sigma_weight": 0.1,
          "photons_per_aj": 7.8125, "act_bits": 8,
          "baselines": {"fp_acc": 0.9, "quant_acc": null},
          "artifacts": {},
          "sites": [
            {"name": "a", "kind": "conv", "n_dot": 27, "n_channels": 4,
             "macs_per_channel": 10.0, "e_offset": 0,
             "in_lo": -1, "in_hi": 1, "in_lo_clip": -1, "in_hi_clip": 1,
             "out_lo": 0, "out_hi": 2, "out_lo_clip": 0, "out_hi_clip": 2,
             "w_lo_layer": -0.5, "w_hi_layer": 0.5, "w_lo": [], "w_hi": []},
            {"name": "b", "kind": "dense", "n_dot": 8, "n_channels": 1,
             "macs_per_channel": 8.0, "e_offset": 4,
             "in_lo": 0, "in_hi": 1, "in_lo_clip": 0, "in_hi_clip": 1,
             "out_lo": -3, "out_hi": 3, "out_lo_clip": -3, "out_hi_clip": 3,
             "w_lo_layer": -1, "w_hi_layer": 1, "w_lo": [], "w_hi": []}
          ]
        }"#;
        ModelMeta::parse(text).unwrap()
    }

    #[test]
    fn sensitivity_ranking_orders_sites_by_learned_energy() {
        let r = TrainResult {
            e: vec![],
            e_per_layer: vec![4.0, 32.0, 4.0, 16.0],
            avg_e: 0.0,
            loss_history: vec![],
            final_acc: 0.0,
        };
        // Highest learned energy first; the 4.0 tie keeps site order.
        assert_eq!(r.sensitivity_ranking(), vec![1, 3, 0, 2]);
    }

    #[test]
    fn expand_compress_roundtrip_per_layer() {
        let m = meta();
        let loge = vec![1.0f32, 3.0];
        let full = expand(&m, Granularity::PerLayer, &loge);
        assert_eq!(full, vec![1.0, 1.0, 1.0, 1.0, 3.0]);
        let grad = vec![0.5f32, 0.5, 0.5, 0.5, 2.0];
        let c = compress(&m, Granularity::PerLayer, &grad);
        assert_eq!(c, vec![2.0, 2.0]);
    }

    #[test]
    fn per_channel_is_identity() {
        let m = meta();
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(expand(&m, Granularity::PerChannel, &v), v);
        assert_eq!(compress(&m, Granularity::PerChannel, &v), v);
    }

    #[test]
    fn penalty_activates_iff_budget_exceeded() {
        let m = meta();
        // Total energy at e = 1 everywhere: 4*10 + 8 = 48.
        let e = vec![1.0f32; 5];
        let lam = 8.0;
        // Budget above the total: inactive, zero everywhere.
        let (p, g) = eq14_penalty(&m, &e, lam, (48.0f64 * 2.0).ln() as f32);
        assert_eq!(p, 0.0);
        assert!(g.iter().all(|&v| v == 0.0));
        // Budget exactly at the total: still inactive (max(0, 0)).
        let (p, _) = eq14_penalty(&m, &e, lam, 48.0f64.ln() as f32);
        assert!(p.abs() < 1e-6, "boundary penalty {p}");
        // Budget below the total: active, value = lam * excess.
        let log_emax = (48.0f64 / 4.0).ln() as f32;
        let (p, g) = eq14_penalty(&m, &e, lam, log_emax);
        assert!((p - lam * 4.0f32.ln()).abs() < 1e-5, "penalty {p}");
        assert!(g.iter().all(|&v| v > 0.0), "active grad positive: {g:?}");
    }

    #[test]
    fn penalty_gradient_pushes_log_e_down_and_sums_to_lambda() {
        let m = meta();
        let e = vec![2.0f32, 2.0, 2.0, 2.0, 8.0];
        let lam = 2.0;
        let (_, g) = eq14_penalty(&m, &e, lam, 0.0); // budget = 1 unit
        // A positive gradient on log-E means `param -= lr * grad`
        // shrinks every energy: the barrier only ever pushes down.
        assert!(g.iter().all(|&v| v > 0.0));
        // The per-channel shares are energy-weighted and total lambda.
        let sum: f32 = g.iter().sum();
        assert!((sum - lam).abs() < 1e-5, "grad sum {sum} != lam {lam}");
        // Channel 4 (8 macs at e=8) outweighs channel 0 (10 macs, e=2).
        assert!(g[4] > g[0]);
        // And matches a numerical derivative of the penalty value.
        let h = 1e-3f32;
        let mut ep = e.clone();
        ep[0] *= h.exp();
        let (p0, _) = eq14_penalty(&m, &e, lam, 0.0);
        let (p1, _) = eq14_penalty(&m, &ep, lam, 0.0);
        let fd = (p1 - p0) / h;
        // 5e-3 tolerance: the f32 rounding of the two penalty values is
        // amplified by the 1/h division.
        assert!((fd - g[0]).abs() < 5e-3, "fd {fd} vs analytic {}", g[0]);
    }

    #[test]
    fn paper_lambdas() {
        assert_eq!(TrainCfg::paper_lambda("shot"), 2.0);
        assert_eq!(TrainCfg::paper_lambda("shot_photonq"), 2.0);
        assert_eq!(TrainCfg::paper_lambda("thermal"), 8.0);
        assert_eq!(TrainCfg::paper_lambda("weight"), 8.0);
    }
}
