//! Adam optimizer (Kingma & Ba 2014) over a flat f32 parameter vector.
//! The paper trains energy allocations with Adam at lr = 0.01 (App. A).

#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl Adam {
    pub fn new(n: usize, lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    /// In-place parameter update from a gradient.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] =
                self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mh = self.m[i] / b1t;
            let vh = self.v[i] / b2t;
            params[i] -= self.lr * mh / (vh.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = sum (x - 3)^2, grad = 2(x - 3)
        let mut x = vec![0.0f32; 4];
        let mut opt = Adam::new(4, 0.1);
        for _ in 0..500 {
            let g: Vec<f32> = x.iter().map(|&v| 2.0 * (v - 3.0)).collect();
            opt.step(&mut x, &g);
        }
        for v in &x {
            assert!((v - 3.0).abs() < 1e-2, "{v}");
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        let mut x = vec![0.0f32];
        let mut opt = Adam::new(1, 0.01);
        opt.step(&mut x, &[5.0]);
        // Adam's first step is ~lr regardless of gradient scale.
        assert!((x[0] + 0.01).abs() < 1e-4, "{}", x[0]);
    }

    /// Textbook Adam (Kingma & Ba, Algorithm 1) in f64: the golden
    /// reference the production update must track.
    fn reference_step(
        x: &mut f64,
        m: &mut f64,
        v: &mut f64,
        t: u32,
        g: f64,
        lr: f64,
    ) {
        let (b1, b2, eps) = (0.9, 0.999, 1e-8);
        *m = b1 * *m + (1.0 - b1) * g;
        *v = b2 * *v + (1.0 - b2) * g * g;
        let mh = *m / (1.0 - b1.powi(t as i32));
        let vh = *v / (1.0 - b2.powi(t as i32));
        *x -= lr * mh / (vh.sqrt() + eps);
    }

    #[test]
    fn bias_correction_matches_textbook_reference() {
        // Drive both implementations through a deterministic, wildly
        // varying gradient sequence; the bias-corrected moments must
        // agree step for step (f32 vs f64 tolerance only). Early steps
        // are where bias correction matters most — an uncorrected
        // first step would be ~sqrt(1/(1-b2))/(1/(1-b1)) = 3.16x lr.
        let mut x = vec![0.0f32];
        let mut opt = Adam::new(1, 0.1);
        let (mut xr, mut mr, mut vr) = (0.0f64, 0.0, 0.0);
        for t in 1..=50u32 {
            let g = ((t as f64 * 0.7).sin() * 3.0) + 0.25;
            opt.step(&mut x, &[g as f32]);
            reference_step(&mut xr, &mut mr, &mut vr, t, g, 0.1);
            assert!(
                (x[0] as f64 - xr).abs() < 1e-4,
                "step {t}: impl {} vs reference {xr}",
                x[0]
            );
        }
    }

    #[test]
    fn constant_gradient_steps_are_lr_sized_at_any_scale() {
        // With a constant gradient the bias-corrected moments are exact
        // (mh = g, vh = g^2), so every step is lr * sign(g) regardless
        // of gradient magnitude: after k steps, x = -k * lr.
        for &g in &[5.0f32, 1e-4, 1e4] {
            let mut x = vec![0.0f32];
            let mut opt = Adam::new(1, 0.01);
            for k in 1..=10 {
                opt.step(&mut x, &[g]);
                let want = -0.01 * k as f32;
                // 5e-5: at g = 1e-4 the eps term shaves ~1e-4 of each
                // step (eps/|g| relative), accumulating to ~1e-5.
                assert!(
                    (x[0] - want).abs() < 5e-5,
                    "grad {g}, step {k}: {} vs {want}",
                    x[0]
                );
            }
        }
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let mut x = vec![0.0f32; 2];
        Adam::new(2, 0.1).step(&mut x, &[1.0]);
    }
}
