//! Adam optimizer (Kingma & Ba 2014) over a flat f32 parameter vector.
//! The paper trains energy allocations with Adam at lr = 0.01 (App. A).

#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl Adam {
    pub fn new(n: usize, lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    /// In-place parameter update from a gradient.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] =
                self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mh = self.m[i] / b1t;
            let vh = self.v[i] / b2t;
            params[i] -= self.lr * mh / (vh.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = sum (x - 3)^2, grad = 2(x - 3)
        let mut x = vec![0.0f32; 4];
        let mut opt = Adam::new(4, 0.1);
        for _ in 0..500 {
            let g: Vec<f32> = x.iter().map(|&v| 2.0 * (v - 3.0)).collect();
            opt.step(&mut x, &g);
        }
        for v in &x {
            assert!((v - 3.0).abs() < 1e-2, "{v}");
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        let mut x = vec![0.0f32];
        let mut opt = Adam::new(1, 0.01);
        opt.step(&mut x, &[5.0]);
        // Adam's first step is ~lr regardless of gradient scale.
        assert!((x[0] + 0.01).abs() < 1e-4, "{}", x[0]);
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let mut x = vec![0.0f32; 2];
        Adam::new(2, 0.1).step(&mut x, &[1.0]);
    }
}
