//! Binary search for the minimum energy/MAC at bounded accuracy loss
//! (paper Sec. VI-A: "<2% degradation, within 0.1%, by binary search on
//! the target energy/MAC").

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::ops::ModelOps;

#[derive(Clone, Debug)]
pub struct SearchCfg {
    /// Allowed accuracy degradation vs baseline (paper: 0.02).
    pub max_degradation: f64,
    /// Multiplicative convergence tolerance on energy (hi/lo - 1).
    pub rel_tol: f64,
    /// Bisection iteration cap.
    pub max_iters: usize,
    /// Eval sampling: batches and noise seeds per accuracy estimate.
    pub eval_batches: usize,
    pub eval_seeds: Vec<u32>,
}

impl Default for SearchCfg {
    fn default() -> Self {
        SearchCfg {
            max_degradation: 0.02,
            rel_tol: 0.08,
            max_iters: 10,
            eval_batches: 8,
            eval_seeds: vec![0],
        }
    }
}

#[derive(Clone, Debug)]
pub struct SearchResult {
    /// Minimum average energy/MAC meeting the accuracy bound.
    pub min_avg_e: f64,
    /// Accuracy at that energy.
    pub acc: f64,
    /// (energy, accuracy) probes, in evaluation order.
    pub probes: Vec<(f64, f64)>,
}

/// Bisect the average energy/MAC. `eval_at(avg_e)` must return accuracy
/// at that (scaled) energy; `baseline` is the clean reference accuracy.
///
/// Precondition handling: grows `hi` geometrically until feasible (4x
/// per step, up to 8 steps); if even the grown upper bound misses the
/// accuracy target the search returns a contextful `Err` (target,
/// bound reached, best probe) rather than silently capping at an
/// energy that violates `max_degradation`. A feasible `lo` is returned
/// directly (it is already the answer).
pub fn binary_search_emax<F>(
    mut eval_at: F,
    baseline: f64,
    mut lo: f64,
    mut hi: f64,
    cfg: &SearchCfg,
) -> Result<SearchResult>
where
    F: FnMut(f64) -> Result<f64>,
{
    let target = baseline - cfg.max_degradation;
    let mut probes = Vec::new();
    let mut feasible: Option<(f64, f64)> = None;

    // Ensure hi is feasible.
    for _ in 0..8 {
        let acc = eval_at(hi)?;
        probes.push((hi, acc));
        if acc >= target {
            feasible = Some((hi, acc));
            break;
        }
        lo = hi;
        hi *= 4.0;
    }
    let Some(mut best) = feasible else {
        // Even the grown upper bound fails: no energy in (or above) the
        // bracket meets the bound — surface that instead of returning
        // an energy that silently violates `max_degradation`.
        let (best_e, best_acc) = probes
            .iter()
            .copied()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        bail!(
            "accuracy target {target:.4} (baseline {baseline:.4} - \
             {:.4} allowed degradation) is unreachable: best probe \
             reached acc {best_acc:.4} at energy {best_e:.4} after \
             growing the upper bound to {:.4} over {} probes",
            cfg.max_degradation,
            probes.last().unwrap().0,
            probes.len()
        );
    };

    // Ensure lo is infeasible (otherwise lo itself is the answer).
    let acc_lo = eval_at(lo)?;
    probes.push((lo, acc_lo));
    if acc_lo >= target {
        return Ok(SearchResult { min_avg_e: lo, acc: acc_lo, probes });
    }

    for _ in 0..cfg.max_iters {
        if hi / lo - 1.0 <= cfg.rel_tol {
            break;
        }
        let mid = (lo * hi).sqrt(); // geometric bisection
        let acc = eval_at(mid)?;
        probes.push((mid, acc));
        if acc >= target {
            hi = mid;
            best = (mid, acc);
        } else {
            lo = mid;
        }
    }
    Ok(SearchResult { min_avg_e: best.0, acc: best.1, probes })
}

/// Evaluate a model's noisy accuracy with a globally scaled energy
/// vector: e_scaled = shape * (avg_e / avg(shape)).
pub fn eval_scaled(
    ops: &dyn ModelOps,
    data: &Dataset,
    fwd_tag: &str,
    shape: &[f32],
    avg_e: f64,
    cfg: &SearchCfg,
) -> Result<f64> {
    let meta = ops.meta();
    let cur = meta.avg_energy_per_mac(shape);
    let scale = (avg_e / cur) as f32;
    let e: Vec<f32> = shape.iter().map(|&v| v * scale).collect();
    ops.eval_noisy(fwd_tag, data, &e, &cfg.eval_seeds, cfg.eval_batches)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SearchCfg {
        SearchCfg { rel_tol: 0.01, max_iters: 40, ..Default::default() }
    }

    #[test]
    fn finds_threshold_of_monotone_curve() {
        // acc(E) = 0.9 - 0.5/E: target 0.88 -> E* = 25.
        let r = binary_search_emax(
            |e| Ok(0.9 - 0.5 / e),
            0.9,
            0.1,
            100.0,
            &cfg(),
        )
        .unwrap();
        assert!((r.min_avg_e - 25.0).abs() / 25.0 < 0.05, "{}", r.min_avg_e);
        assert!(r.acc >= 0.88);
    }

    #[test]
    fn grows_hi_when_infeasible() {
        // Needs E >= 400 to be feasible; initial hi = 10.
        let r = binary_search_emax(
            |e| Ok(if e >= 400.0 { 0.9 } else { 0.5 }),
            0.9,
            1.0,
            10.0,
            &cfg(),
        )
        .unwrap();
        assert!(r.min_avg_e >= 400.0);
        assert!(r.min_avg_e <= 640.0 * 1.02, "{}", r.min_avg_e);
    }

    #[test]
    fn returns_lo_if_already_feasible() {
        let r = binary_search_emax(|_| Ok(0.95), 0.9, 0.5, 10.0, &cfg()).unwrap();
        assert_eq!(r.min_avg_e, 0.5);
    }

    #[test]
    fn impossible_target_errors_with_context() {
        // A flat 0.1 accuracy can never reach the 0.88 target: the
        // search must refuse (never return an energy violating the
        // degradation bound) and say why.
        let err = binary_search_emax(|_| Ok(0.1), 0.9, 1.0, 2.0, &cfg())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unreachable"), "{msg}");
        assert!(msg.contains("0.8800"), "target missing: {msg}");
        assert!(msg.contains("0.1000"), "best probe missing: {msg}");
        // hi grew 4x per probe for 8 probes: 2 * 4^7 = 32768.
        assert!(msg.contains("32768"), "grown bound missing: {msg}");
    }

    #[test]
    fn barely_feasible_target_still_succeeds() {
        // The other branch of the same check: feasibility appears only
        // after the growth loop's last doubling — Ok, not Err.
        let r = binary_search_emax(
            |e| Ok(if e >= 30_000.0 { 0.9 } else { 0.1 }),
            0.9,
            1.0,
            2.0,
            &cfg(),
        )
        .unwrap();
        assert!(r.acc >= 0.88);
        assert!(r.min_avg_e >= 30_000.0);
    }
}
