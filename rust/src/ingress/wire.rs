//! Length-prefixed binary wire protocol for socket ingress.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! | u32 len | u8 type | payload (len - 1 bytes) |
//! ```
//!
//! `len` counts the type byte plus the payload, so a zero-length frame
//! is malformed by construction. Frame types:
//!
//! | type | name     | payload |
//! |------|----------|---------|
//! | 1    | request  | `u32 corr`, `u8 model_len`, model (utf-8), `u8 kind` (0 = f32, 1 = i32), `u32 n`, `n` 4-byte elements |
//! | 2    | response | `u32 corr`, `u8 status` ([`ShedReason`] wire code), `i32 pred`, `u32 latency_us`, `u32 batch_size`, `f64 energy`, `u32 device`, `u32 n_logits`, `n_logits` f32 |
//!
//! `corr` is a client-chosen correlation id echoed verbatim on the
//! response, so clients may pipeline requests on one connection and
//! match completions out of order. `status` is `0` for a served
//! response and a [`ShedReason`] wire code for a typed shed — shed
//! *status frames*, not closed connections, are how overload reads to
//! a remote client.
//!
//! Every malformed input maps to a typed [`ProtoError`] (never a
//! panic): the server counts it, closes that connection, and keeps
//! serving the rest.

use crate::coordinator::request::{InferResponse, ShedReason};
use crate::data::Features;

/// Hard cap on one frame's `len` field. Bounds per-connection decode
/// memory: a malicious 4 GiB length prefix is rejected before any
/// buffering happens.
pub const MAX_FRAME: usize = 1 << 20;

pub const FRAME_REQUEST: u8 = 1;
pub const FRAME_RESPONSE: u8 = 2;

/// Typed wire-protocol violation. Each variant is a distinct client
/// bug; the server closes the offending connection and increments
/// `protocol_errors`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtoError {
    /// Length prefix exceeds [`MAX_FRAME`].
    Oversize { len: usize },
    /// Length prefix of zero (no type byte).
    EmptyFrame,
    /// Type byte names no known frame.
    UnknownFrameType(u8),
    /// Response status byte names no [`ShedReason`].
    UnknownStatus(u8),
    /// Feature kind byte names no [`Features`] variant.
    UnknownFeatureKind(u8),
    /// Payload ended before its declared fields did.
    Truncated,
    /// Payload continued past its declared fields.
    TrailingBytes,
    /// Model name is not valid UTF-8.
    BadModelName,
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Oversize { len } => {
                write!(f, "frame length {len} exceeds {MAX_FRAME}")
            }
            ProtoError::EmptyFrame => write!(f, "zero-length frame"),
            ProtoError::UnknownFrameType(t) => {
                write!(f, "unknown frame type {t}")
            }
            ProtoError::UnknownStatus(s) => {
                write!(f, "unknown shed status {s}")
            }
            ProtoError::UnknownFeatureKind(k) => {
                write!(f, "unknown feature kind {k}")
            }
            ProtoError::Truncated => write!(f, "truncated frame payload"),
            ProtoError::TrailingBytes => {
                write!(f, "trailing bytes after frame payload")
            }
            ProtoError::BadModelName => {
                write!(f, "model name is not utf-8")
            }
        }
    }
}

impl std::error::Error for ProtoError {}

/// A decoded request frame.
#[derive(Clone, Debug)]
pub struct WireRequest {
    pub corr: u32,
    pub model: String,
    pub x: Features,
}

/// A decoded response frame.
#[derive(Clone, Debug)]
pub struct WireResponse {
    pub corr: u32,
    /// `ShedReason::None` for a served response, the typed cause for a
    /// shed-status frame.
    pub status: ShedReason,
    pub pred: i32,
    pub latency_us: u32,
    pub batch_size: u32,
    pub energy: f64,
    pub device: u32,
    pub logits: Vec<f32>,
}

impl WireResponse {
    /// Project a coordinator [`InferResponse`] onto the wire (the
    /// typed `reason` becomes the status byte; latency saturates at
    /// `u32::MAX` microseconds).
    pub fn from_infer(corr: u32, r: &InferResponse) -> WireResponse {
        WireResponse {
            corr,
            status: r.reason,
            pred: r.pred,
            latency_us: r.latency_us.min(u32::MAX as u64) as u32,
            batch_size: r.batch_size.min(u32::MAX as usize) as u32,
            energy: r.energy,
            device: r.device,
            logits: r.logits.clone(),
        }
    }
}

/// Any decoded frame.
#[derive(Clone, Debug)]
pub enum Frame {
    Request(WireRequest),
    Response(WireResponse),
}

fn frame(out: &mut Vec<u8>, ty: u8, body: impl FnOnce(&mut Vec<u8>)) {
    let at = out.len();
    out.extend_from_slice(&[0u8; 4]);
    out.push(ty);
    body(out);
    let len = (out.len() - at - 4) as u32;
    out[at..at + 4].copy_from_slice(&len.to_le_bytes());
}

/// Append one request frame. Model names longer than 255 bytes are
/// truncated (the length rides in one byte).
pub fn encode_request(
    out: &mut Vec<u8>,
    corr: u32,
    model: &str,
    x: &Features,
) {
    frame(out, FRAME_REQUEST, |o| {
        o.extend_from_slice(&corr.to_le_bytes());
        let m = &model.as_bytes()[..model.len().min(255)];
        o.push(m.len() as u8);
        o.extend_from_slice(m);
        match x {
            Features::F32(v) => {
                o.push(0);
                o.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for e in v {
                    o.extend_from_slice(&e.to_le_bytes());
                }
            }
            Features::I32(v) => {
                o.push(1);
                o.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for e in v {
                    o.extend_from_slice(&e.to_le_bytes());
                }
            }
        }
    });
}

/// Append one response frame.
pub fn encode_response(out: &mut Vec<u8>, r: &WireResponse) {
    frame(out, FRAME_RESPONSE, |o| {
        o.extend_from_slice(&r.corr.to_le_bytes());
        o.push(r.status.wire_code());
        o.extend_from_slice(&r.pred.to_le_bytes());
        o.extend_from_slice(&r.latency_us.to_le_bytes());
        o.extend_from_slice(&r.batch_size.to_le_bytes());
        o.extend_from_slice(&r.energy.to_bits().to_le_bytes());
        o.extend_from_slice(&r.device.to_le_bytes());
        o.extend_from_slice(&(r.logits.len() as u32).to_le_bytes());
        for l in &r.logits {
            o.extend_from_slice(&l.to_le_bytes());
        }
    });
}

/// Bounded cursor over one frame's payload.
struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.b.len() - self.i < n {
            return Err(ProtoError::Truncated);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32, ProtoError> {
        Ok(self.u32()? as i32)
    }

    fn f64(&mut self) -> Result<f64, ProtoError> {
        let raw = u64::from_le_bytes(self.take(8)?.try_into().unwrap());
        Ok(f64::from_bits(raw))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, ProtoError> {
        let raw = self.take(n.checked_mul(4).ok_or(ProtoError::Truncated)?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i32s(&mut self, n: usize) -> Result<Vec<i32>, ProtoError> {
        let raw = self.take(n.checked_mul(4).ok_or(ProtoError::Truncated)?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<(), ProtoError> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(ProtoError::TrailingBytes)
        }
    }
}

fn parse_frame(p: &[u8]) -> Result<Frame, ProtoError> {
    let mut rd = Rd { b: p, i: 1 };
    match p[0] {
        FRAME_REQUEST => {
            let corr = rd.u32()?;
            let mlen = rd.u8()? as usize;
            let model = std::str::from_utf8(rd.take(mlen)?)
                .map_err(|_| ProtoError::BadModelName)?
                .to_string();
            let kind = rd.u8()?;
            let n = rd.u32()? as usize;
            let x = match kind {
                0 => Features::F32(rd.f32s(n)?),
                1 => Features::I32(rd.i32s(n)?),
                k => return Err(ProtoError::UnknownFeatureKind(k)),
            };
            rd.done()?;
            Ok(Frame::Request(WireRequest { corr, model, x }))
        }
        FRAME_RESPONSE => {
            let corr = rd.u32()?;
            let code = rd.u8()?;
            let status = ShedReason::from_wire(code)
                .ok_or(ProtoError::UnknownStatus(code))?;
            let pred = rd.i32()?;
            let latency_us = rd.u32()?;
            let batch_size = rd.u32()?;
            let energy = rd.f64()?;
            let device = rd.u32()?;
            let n = rd.u32()? as usize;
            let logits = rd.f32s(n)?;
            rd.done()?;
            Ok(Frame::Response(WireResponse {
                corr,
                status,
                pred,
                latency_us,
                batch_size,
                energy,
                device,
                logits,
            }))
        }
        t => Err(ProtoError::UnknownFrameType(t)),
    }
}

/// Incremental frame decoder: feed it raw socket bytes in whatever
/// pieces `read` returns; it yields complete frames as they reassemble
/// and reports any protocol violation as a typed error.
#[derive(Default)]
pub struct Decoder {
    buf: Vec<u8>,
    at: usize,
}

impl Decoder {
    pub fn new() -> Decoder {
        Decoder::default()
    }

    /// Buffer more bytes from the socket.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a complete frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.at
    }

    /// Pop the next complete frame. `Ok(None)` means more bytes are
    /// needed; an `Err` poisons the stream (the caller closes the
    /// connection, so no resynchronization is attempted).
    pub fn next(&mut self) -> Result<Option<Frame>, ProtoError> {
        // Reclaim consumed prefix lazily, so a long-lived connection
        // does not grow its buffer without bound.
        if self.at > 0 && (self.at == self.buf.len() || self.at >= 65_536) {
            self.buf.drain(..self.at);
            self.at = 0;
        }
        let avail = self.buf.len() - self.at;
        if avail < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(
            self.buf[self.at..self.at + 4].try_into().unwrap(),
        ) as usize;
        if len == 0 {
            return Err(ProtoError::EmptyFrame);
        }
        if len > MAX_FRAME {
            return Err(ProtoError::Oversize { len });
        }
        if avail < 4 + len {
            return Ok(None);
        }
        let frame = parse_frame(&self.buf[self.at + 4..self.at + 4 + len]);
        self.at += 4 + len;
        frame.map(Some)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decode_all(bytes: &[u8]) -> Vec<Frame> {
        let mut d = Decoder::new();
        d.extend(bytes);
        let mut out = Vec::new();
        while let Some(f) = d.next().unwrap() {
            out.push(f);
        }
        assert_eq!(d.buffered(), 0);
        out
    }

    #[test]
    fn request_roundtrips_both_feature_kinds() {
        let mut bytes = Vec::new();
        encode_request(
            &mut bytes,
            7,
            "synth",
            &Features::F32(vec![1.5, -2.0, 0.25]),
        );
        encode_request(&mut bytes, 8, "tok", &Features::I32(vec![3, -4]));
        let frames = decode_all(&bytes);
        assert_eq!(frames.len(), 2);
        match &frames[0] {
            Frame::Request(r) => {
                assert_eq!(r.corr, 7);
                assert_eq!(r.model, "synth");
                match &r.x {
                    Features::F32(v) => {
                        assert_eq!(v, &[1.5, -2.0, 0.25])
                    }
                    Features::I32(_) => panic!("wrong feature kind"),
                }
            }
            Frame::Response(_) => panic!("expected request"),
        }
        match &frames[1] {
            Frame::Request(r) => {
                assert_eq!(r.corr, 8);
                match &r.x {
                    Features::I32(v) => assert_eq!(v, &[3, -4]),
                    Features::F32(_) => panic!("wrong feature kind"),
                }
            }
            Frame::Response(_) => panic!("expected request"),
        }
    }

    #[test]
    fn response_roundtrips_every_status() {
        for reason in ShedReason::ALL {
            let resp = WireResponse {
                corr: 42,
                status: reason,
                pred: -1,
                latency_us: 1234,
                batch_size: 8,
                energy: 32_000.5,
                device: 3,
                logits: vec![0.1, 0.9],
            };
            let mut bytes = Vec::new();
            encode_response(&mut bytes, &resp);
            let frames = decode_all(&bytes);
            assert_eq!(frames.len(), 1);
            match &frames[0] {
                Frame::Response(r) => {
                    assert_eq!(r.corr, 42);
                    assert_eq!(r.status, reason);
                    assert_eq!(r.pred, -1);
                    assert_eq!(r.latency_us, 1234);
                    assert_eq!(r.batch_size, 8);
                    assert_eq!(r.energy, 32_000.5);
                    assert_eq!(r.device, 3);
                    assert_eq!(r.logits, vec![0.1, 0.9]);
                }
                Frame::Request(_) => panic!("expected response"),
            }
        }
    }

    #[test]
    fn split_reads_reassemble_byte_by_byte() {
        let mut bytes = Vec::new();
        encode_request(&mut bytes, 1, "m", &Features::F32(vec![1.0; 16]));
        encode_request(&mut bytes, 2, "m", &Features::F32(vec![2.0; 16]));
        let mut d = Decoder::new();
        let mut got = Vec::new();
        // Worst-case fragmentation: one byte per read.
        for b in &bytes {
            d.extend(&[*b]);
            while let Some(f) = d.next().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got.len(), 2);
        match &got[1] {
            Frame::Request(r) => assert_eq!(r.corr, 2),
            Frame::Response(_) => panic!("expected request"),
        }
    }

    #[test]
    fn from_infer_carries_the_typed_reason() {
        let shed =
            InferResponse::rejected_for(9, ShedReason::QueueHardLimit);
        let w = WireResponse::from_infer(77, &shed);
        assert_eq!(w.corr, 77);
        assert_eq!(w.status, ShedReason::QueueHardLimit);
        assert!(w.logits.is_empty());
        let ok = InferResponse::from_logits(3, vec![0.2, 0.8], 150, 4, 9.0, 1);
        let w = WireResponse::from_infer(78, &ok);
        assert_eq!(w.status, ShedReason::None);
        assert_eq!(w.pred, 1);
        assert_eq!(w.latency_us, 150);
    }

    #[test]
    fn malformed_frames_are_typed_errors() {
        // Oversize length prefix: rejected before buffering the body.
        let mut d = Decoder::new();
        d.extend(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert_eq!(
            d.next().unwrap_err(),
            ProtoError::Oversize { len: MAX_FRAME + 1 }
        );

        // Zero-length frame.
        let mut d = Decoder::new();
        d.extend(&0u32.to_le_bytes());
        assert_eq!(d.next().unwrap_err(), ProtoError::EmptyFrame);

        // Unknown frame type.
        let mut d = Decoder::new();
        d.extend(&1u32.to_le_bytes());
        d.extend(&[9]);
        assert_eq!(d.next().unwrap_err(), ProtoError::UnknownFrameType(9));

        // Truncated payload: type says request, body is empty.
        let mut d = Decoder::new();
        d.extend(&1u32.to_le_bytes());
        d.extend(&[FRAME_REQUEST]);
        assert_eq!(d.next().unwrap_err(), ProtoError::Truncated);

        // Trailing bytes after a complete request body.
        let mut good = Vec::new();
        encode_request(&mut good, 1, "m", &Features::F32(vec![]));
        let mut bad = good.clone();
        bad.push(0xFF);
        let len =
            u32::from_le_bytes(bad[0..4].try_into().unwrap()) + 1;
        bad[0..4].copy_from_slice(&len.to_le_bytes());
        let mut d = Decoder::new();
        d.extend(&bad);
        assert_eq!(d.next().unwrap_err(), ProtoError::TrailingBytes);

        // Unknown status byte in a response.
        let mut resp = Vec::new();
        encode_response(
            &mut resp,
            &WireResponse {
                corr: 1,
                status: ShedReason::None,
                pred: 0,
                latency_us: 0,
                batch_size: 0,
                energy: 0.0,
                device: 0,
                logits: vec![],
            },
        );
        resp[9] = 200; // status byte: 4 len + 1 type + 4 corr
        let mut d = Decoder::new();
        d.extend(&resp);
        assert_eq!(d.next().unwrap_err(), ProtoError::UnknownStatus(200));

        // Unknown feature kind in a request.
        let mut req = Vec::new();
        encode_request(&mut req, 1, "m", &Features::F32(vec![]));
        // kind byte: 4 len + 1 type + 4 corr + 1 mlen + 1 model byte.
        req[11] = 7;
        let mut d = Decoder::new();
        d.extend(&req);
        assert_eq!(d.next().unwrap_err(), ProtoError::UnknownFeatureKind(7));

        // Bad UTF-8 model name.
        let mut req = Vec::new();
        encode_request(&mut req, 1, "mm", &Features::F32(vec![]));
        req[10] = 0xFF; // first model byte
        let mut d = Decoder::new();
        d.extend(&req);
        assert_eq!(d.next().unwrap_err(), ProtoError::BadModelName);
    }

    #[test]
    fn long_model_names_truncate_to_one_length_byte() {
        let name = "x".repeat(300);
        let mut bytes = Vec::new();
        encode_request(&mut bytes, 1, &name, &Features::F32(vec![]));
        match &decode_all(&bytes)[0] {
            Frame::Request(r) => assert_eq!(r.model.len(), 255),
            Frame::Response(_) => panic!("expected request"),
        }
    }

    #[test]
    fn decoder_reclaims_consumed_prefix() {
        let mut d = Decoder::new();
        let mut bytes = Vec::new();
        encode_request(&mut bytes, 1, "m", &Features::F32(vec![0.0; 64]));
        for _ in 0..2_000 {
            d.extend(&bytes);
            assert!(d.next().unwrap().is_some());
        }
        // 2000 × ~280-byte frames passed through; the buffer must stay
        // bounded by the compaction threshold, not grow to ~560 KB.
        assert!(d.buf.capacity() < 300_000, "cap {}", d.buf.capacity());
    }
}
