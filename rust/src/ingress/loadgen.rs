//! Closed-loop socket load generator.
//!
//! Drives real TCP connections against an [`IngressServer`] using the
//! seeded [`crate::sim::traffic`] arrival distributions
//! (steady/diurnal/heavy-tail), so the same generators that feed the
//! deterministic simulator also exercise the socket path. One thread,
//! its own small epoll instance, nonblocking sockets throughout.
//!
//! *Closed-loop*: each connection holds at most
//! `max_outstanding_per_conn` requests in flight; the next request is
//! written only when a completion frees the window (or its arrival
//! time has not come yet). Under overload, throughput therefore tracks
//! what the server actually completes — including typed shed frames —
//! instead of piling unbounded requests into the kernel.
//!
//! The report carries client-observed latency percentiles, shed
//! counts by typed reason, and a per-connection
//! [`ConnAccounting`] ledger for the socket conservation invariant
//! (`responses + typed_sheds == frames_sent`, see
//! [`crate::sim::check_connection_conservation`]).
//!
//! [`IngressServer`]: crate::ingress::IngressServer

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use super::{sys, wire};
use crate::coordinator::ShedReason;
use crate::data::Features;
use crate::sim::{ConnAccounting, SimEvent};

/// Load-generator knobs.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Concurrent client connections.
    pub conns: usize,
    /// Closed-loop window per connection.
    pub max_outstanding_per_conn: u32,
    /// Divide traffic timestamps by this: `1.0` replays the schedule
    /// in real time; a large value makes every arrival due at once, so
    /// pacing degenerates to a pure closed loop.
    pub time_scale: f64,
    /// Feature-vector length of the synthetic requests.
    pub feature_len: usize,
    /// Wall-clock cap; the run reports `timed_out` when it trips.
    pub timeout: Duration,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            conns: 4,
            max_outstanding_per_conn: 1,
            time_scale: 1.0,
            feature_len: 4,
            timeout: Duration::from_secs(30),
        }
    }
}

/// What a load run observed, from the client side of the sockets.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    /// Request frames fully written.
    pub sent: u64,
    /// Served responses received.
    pub served: u64,
    /// Typed shed frames received.
    pub shed: u64,
    /// Shed counts by typed reason (indexed by wire code).
    pub sheds_by_reason: [u64; 7],
    /// Client-observed round-trip latencies, microseconds, served
    /// responses only (raw, for percentile math downstream).
    pub latencies_us: Vec<u64>,
    /// Summed energy (aJ) reported on served responses.
    pub energy_aj: f64,
    /// Per-connection conservation ledgers.
    pub per_conn: Vec<ConnAccounting>,
    /// Wall time the run took.
    pub elapsed: Duration,
    /// The run hit `LoadgenConfig::timeout` before draining.
    pub timed_out: bool,
}

impl LoadReport {
    fn pct(&self, q: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((v.len() as f64 - 1.0) * q).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn p50_us(&self) -> u64 {
        self.pct(0.50)
    }

    pub fn p95_us(&self) -> u64 {
        self.pct(0.95)
    }

    pub fn p99_us(&self) -> u64 {
        self.pct(0.99)
    }

    /// Fraction of completed requests answered with a shed status.
    pub fn shed_rate(&self) -> f64 {
        let total = self.served + self.shed;
        if total == 0 {
            0.0
        } else {
            self.shed as f64 / total as f64
        }
    }

    /// Mean reported energy per served request (aJ).
    pub fn energy_per_request_aj(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.energy_aj / self.served as f64
        }
    }
}

struct CConn {
    sock: TcpStream,
    dec: wire::Decoder,
    out: Vec<u8>,
    out_at: usize,
    outstanding: u32,
    next_corr: u32,
    /// corr -> send timestamp (ns since run start).
    sent_at: HashMap<u32, u64>,
    acct: ConnAccounting,
    dead: bool,
}

/// Replay `events` (only `SimEvent::Submit` entries matter; `n`-counts
/// expand to individual requests) against a live ingress listener and
/// collect a [`LoadReport`]. Returns `Err` only on setup failures
/// (connect/epoll); mid-run socket errors mark the connection dead and
/// surface as a conservation violation in `per_conn`.
pub fn run_load(
    addr: SocketAddr,
    events: &[SimEvent],
    cfg: &LoadgenConfig,
) -> std::io::Result<LoadReport> {
    // Flatten the schedule: (due_ns, model) per individual request,
    // scaled onto the wall clock.
    let mut schedule: Vec<(u64, String)> = Vec::new();
    for e in events {
        if let SimEvent::Submit { t_ns, model, n } = e {
            let due = (*t_ns as f64 / cfg.time_scale.max(1e-12)) as u64;
            for _ in 0..*n {
                schedule.push((due, model.clone()));
            }
        }
    }
    schedule.sort_by_key(|(t, _)| *t);

    let epoll = sys::Epoll::new()?;
    let mut conns: Vec<CConn> = Vec::with_capacity(cfg.conns.max(1));
    for i in 0..cfg.conns.max(1) {
        let sock = TcpStream::connect(addr)?;
        sock.set_nonblocking(true)?;
        let _ = sock.set_nodelay(true);
        epoll.add(
            std::os::unix::io::AsRawFd::as_raw_fd(&sock),
            i as u64,
            sys::EPOLLIN,
        )?;
        conns.push(CConn {
            sock,
            dec: wire::Decoder::new(),
            out: Vec::new(),
            out_at: 0,
            outstanding: 0,
            next_corr: 1,
            sent_at: HashMap::new(),
            acct: ConnAccounting { conn: i, ..Default::default() },
            dead: false,
        });
    }

    let t0 = Instant::now();
    let mut report = LoadReport::default();
    let x = Features::F32(vec![0.5; cfg.feature_len.max(1)]);
    let mut next_ev = 0usize;
    let mut rr = 0usize; // round-robin cursor over connections
    let mut events_buf =
        vec![sys::EpollEvent { events: 0, data: 0 }; 256];
    let mut rbuf = vec![0u8; 64 * 1024];

    loop {
        let now_ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;

        // Send phase: everything due, window permitting.
        while next_ev < schedule.len() && schedule[next_ev].0 <= now_ns {
            let mut placed = false;
            for k in 0..conns.len() {
                let i = (rr + k) % conns.len();
                let c = &mut conns[i];
                if c.dead || c.outstanding >= cfg.max_outstanding_per_conn
                {
                    continue;
                }
                let corr = c.next_corr;
                c.next_corr = c.next_corr.wrapping_add(1);
                wire::encode_request(
                    &mut c.out,
                    corr,
                    &schedule[next_ev].1,
                    &x,
                );
                c.outstanding += 1;
                c.sent_at.insert(corr, now_ns);
                c.acct.frames_sent += 1;
                report.sent += 1;
                rr = (i + 1) % conns.len();
                placed = true;
                break;
            }
            if !placed {
                break; // closed loop: wait for completions
            }
            next_ev += 1;
        }

        // Flush pending writes on every connection that has any.
        for c in conns.iter_mut() {
            flush_client(c);
        }

        let inflight: u64 =
            conns.iter().map(|c| c.outstanding as u64).sum();
        if next_ev >= schedule.len() && inflight == 0 {
            break; // drained
        }
        if conns.iter().all(|c| c.dead) {
            break;
        }
        if t0.elapsed() > cfg.timeout {
            report.timed_out = true;
            break;
        }

        // Wait for readability (or the next due arrival).
        let wait_ms = if next_ev < schedule.len() {
            let due = schedule[next_ev].0;
            (due.saturating_sub(now_ns) / 1_000_000).clamp(0, 50) as i32
        } else {
            10
        };
        let n = match epoll.wait(&mut events_buf, wait_ms.max(1)) {
            Ok(n) => n,
            Err(ref e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                0
            }
            Err(e) => return Err(e),
        };
        for ev in &events_buf[..n] {
            let idx = ev.data as usize;
            if idx >= conns.len() {
                continue;
            }
            read_client(
                &mut conns[idx],
                &mut rbuf,
                &mut report,
                t0,
            );
        }
    }

    report.elapsed = t0.elapsed();
    report.per_conn = conns.iter().map(|c| c.acct.clone()).collect();
    Ok(report)
}

fn flush_client(c: &mut CConn) {
    if c.dead {
        return;
    }
    while c.out_at < c.out.len() {
        match c.sock.write(&c.out[c.out_at..]) {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(n) => c.out_at += n,
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                break;
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    if c.out_at == c.out.len() {
        c.out.clear();
        c.out_at = 0;
    }
}

fn read_client(
    c: &mut CConn,
    rbuf: &mut [u8],
    report: &mut LoadReport,
    t0: Instant,
) {
    if c.dead {
        return;
    }
    loop {
        let n = match c.sock.read(rbuf) {
            Ok(0) => {
                c.dead = true;
                break;
            }
            Ok(n) => n,
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                break;
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => {
                c.dead = true;
                break;
            }
        };
        c.dec.extend(&rbuf[..n]);
        loop {
            match c.dec.next() {
                Ok(Some(wire::Frame::Response(r))) => {
                    c.outstanding = c.outstanding.saturating_sub(1);
                    let now_ns = t0
                        .elapsed()
                        .as_nanos()
                        .min(u64::MAX as u128)
                        as u64;
                    let rtt_us = c
                        .sent_at
                        .remove(&r.corr)
                        .map(|t| (now_ns - t) / 1_000)
                        .unwrap_or(0);
                    if r.status == ShedReason::None {
                        c.acct.responses += 1;
                        report.served += 1;
                        report.energy_aj += r.energy;
                        report.latencies_us.push(rtt_us);
                    } else {
                        c.acct.typed_sheds += 1;
                        report.shed += 1;
                        let code = r.status.wire_code() as usize;
                        if code < report.sheds_by_reason.len() {
                            report.sheds_by_reason[code] += 1;
                        }
                    }
                }
                Ok(Some(wire::Frame::Request(_))) | Err(_) => {
                    // A server must never send requests or garbage;
                    // count the stream as dead and let conservation
                    // flag the loss.
                    c.dead = true;
                    return;
                }
                Ok(None) => break,
            }
        }
    }
}
