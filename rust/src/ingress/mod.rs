//! Async socket ingress: thousands of connections, one thread.
//!
//! A readiness-driven event loop (epoll via [`sys`], per the vendored-
//! deps offline-build policy — no `tokio`, no `mio`) multiplexes every
//! client connection onto the existing [`Coordinator`] without
//! one-thread-per-connection:
//!
//! ```text
//!                    ┌───────────────── ingress thread ─────────────────┐
//!  clients ══ TCP ══▶│ epoll ─▶ per-conn state machine ─▶ wire::Decoder │
//!                    │   ▲                                      │       │
//!                    │   │ eventfd wake              submit_sink│       │
//!                    └───┼─────────────────────────────────────┼───────┘
//!                        │                                      ▼
//!                   CompletionSink ◀── device workers ◀── Coordinator
//! ```
//!
//! Requests arrive as length-prefixed [`wire`] frames; completions come
//! back through a [`CompletionSink`] that queues them and signals an
//! eventfd, so device workers never block on a socket and the loop
//! never blocks on a device.
//!
//! # Backpressure: degrade first, shed second, never OOM
//!
//! The loop polls [`Coordinator::ingress_reads_allowed`] every
//! iteration. When any model's queue depth crosses its soft admission
//! limit, *read interest is deregistered* (`EPOLLIN` dropped) on every
//! connection: bytes stay in kernel socket buffers and TCP flow control
//! pushes back to clients, so overload cannot pile unbounded decoded
//! requests into process memory. Meanwhile the autotuner is already
//! lowering precision scale; only past the hard limit do typed shed
//! frames go out. Reads resume — hysteresis lives in
//! `AdmissionGate::reads_allowed` — once the queue drains to half the
//! soft limit. A connection whose own write buffer backs up is paused
//! individually the same way.

pub mod loadgen;
pub mod sys;
pub mod wire;

pub use loadgen::{run_load, LoadReport, LoadgenConfig};
pub use wire::{
    Decoder, Frame, ProtoError, WireRequest, WireResponse, MAX_FRAME,
};

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use crate::coordinator::request::{CompletionSink, InferResponse};
use crate::coordinator::Coordinator;
use crate::obs::metrics::{IngressCounters, MetricsSnapshot};
use crate::sim::{Clock, ClockRef};

/// Ingress front-end knobs.
#[derive(Clone, Debug)]
pub struct IngressConfig {
    /// Listen address; port 0 picks an ephemeral port (read it back
    /// with [`IngressServer::local_addr`]).
    pub addr: String,
    /// Connection cap; accepts beyond it are dropped immediately.
    pub max_conns: usize,
    /// Per-connection pending-write cap: a connection that buffers more
    /// encoded response bytes than this has its reads paused until the
    /// client drains half of it.
    pub write_buf_limit: usize,
    /// Upper bound between admission-gate polls when no I/O is ready.
    pub poll_interval: Duration,
}

impl Default for IngressConfig {
    fn default() -> Self {
        IngressConfig {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 16_384,
            write_buf_limit: 256 * 1024,
            poll_interval: Duration::from_millis(5),
        }
    }
}

/// Lock-free ingress counters (the event loop writes, anyone reads).
#[derive(Default)]
struct Counters {
    accepted: AtomicU64,
    active: AtomicU64,
    paused: AtomicU64,
    frames_in: AtomicU64,
    responses_out: AtomicU64,
    sheds_out: AtomicU64,
    protocol_errors: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> IngressCounters {
        IngressCounters {
            accepted: self.accepted.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            paused: self.paused.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            responses_out: self.responses_out.load(Ordering::Relaxed),
            sheds_out: self.sheds_out.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }
}

/// The completion side of the sink path: device workers push
/// `(token, response)` and ring the eventfd; the event loop drains the
/// queue on wake and routes each response back to its connection.
struct SinkInner {
    done: Mutex<Vec<(u64, InferResponse)>>,
    wake: Arc<sys::EventFd>,
}

impl CompletionSink for SinkInner {
    fn complete(&self, token: u64, resp: InferResponse) {
        self.done
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push((token, resp));
        self.wake.signal();
    }
}

const TOK_LISTENER: u64 = u64::MAX;
const TOK_WAKE: u64 = u64::MAX - 1;

/// Submit tokens carry the connection slot in the high half and the
/// client correlation id in the low half, so a completion routes back
/// to its frame without any lookup table.
fn submit_token(slot: usize, corr: u32) -> u64 {
    ((slot as u64) << 32) | corr as u64
}

struct Conn {
    sock: TcpStream,
    fd: std::os::unix::io::RawFd,
    dec: wire::Decoder,
    out: Vec<u8>,
    out_at: usize,
    /// Requests submitted from this connection, not yet completed.
    inflight: u32,
    /// Interest mask currently registered with epoll.
    interest: u32,
    /// Peer closed (EOF/RDHUP) or errored: stop reading, finish
    /// writing what is owed, then close.
    draining: bool,
    /// Paused by this connection's own write-buffer cap (as opposed to
    /// the fleet-wide admission pause).
    local_paused: bool,
    /// Whether this connection is currently counted in the `paused`
    /// gauge (kept exact across both pause causes).
    counted_paused: bool,
    acct_frames: u64,
}

/// A closed connection with completions still in flight. The slot
/// stays occupied (so a new connection cannot claim the token and
/// receive a stale response) until the last completion drains.
enum Slot {
    Open(Box<Conn>),
    Zombie { inflight: u32 },
}

/// Handle to the running ingress thread. Dropping it (or calling
/// [`IngressServer::shutdown`]) stops the loop and closes every
/// connection; the coordinator itself keeps running.
pub struct IngressServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    wake: Arc<sys::EventFd>,
    counters: Arc<Counters>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl IngressServer {
    /// Bind, register with epoll, and spawn the event loop.
    pub fn start(
        coord: Arc<Coordinator>,
        cfg: IngressConfig,
    ) -> std::io::Result<IngressServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let wake = Arc::new(sys::EventFd::new()?);
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(Counters::default());
        let sink = Arc::new(SinkInner {
            done: Mutex::new(Vec::new()),
            wake: wake.clone(),
        });
        let epoll = sys::Epoll::new()?;
        epoll.add(
            std::os::unix::io::AsRawFd::as_raw_fd(&listener),
            TOK_LISTENER,
            sys::EPOLLIN,
        )?;
        epoll.add(wake.raw(), TOK_WAKE, sys::EPOLLIN)?;
        let handle = {
            let stop = stop.clone();
            let wake = wake.clone();
            let counters = counters.clone();
            std::thread::Builder::new()
                .name("ingress".to_string())
                .spawn(move || {
                    event_loop(
                        &coord, &listener, &epoll, &cfg, &stop, &wake,
                        &counters, &sink,
                    );
                })?
        };
        Ok(IngressServer {
            addr,
            stop,
            wake,
            counters,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time ingress counters.
    pub fn counters(&self) -> IngressCounters {
        self.counters.snapshot()
    }

    /// The coordinator's metrics snapshot with this listener's ingress
    /// counters stamped in (the bare coordinator snapshot carries
    /// `ingress: None`).
    pub fn metrics_snapshot(&self, coord: &Coordinator) -> MetricsSnapshot {
        let mut m = coord.metrics_snapshot();
        m.ingress = Some(self.counters.snapshot());
        m
    }

    /// Stop the event loop and join the thread.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.wake.signal();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for IngressServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn wouldblock(e: &std::io::Error) -> bool {
    e.kind() == std::io::ErrorKind::WouldBlock
}

fn interrupted(e: &std::io::Error) -> bool {
    e.kind() == std::io::ErrorKind::Interrupted
}

/// What a per-connection handler decided.
enum After {
    Keep,
    Close,
}

#[allow(clippy::too_many_arguments)]
fn event_loop(
    coord: &Coordinator,
    listener: &TcpListener,
    epoll: &sys::Epoll,
    cfg: &IngressConfig,
    stop: &AtomicBool,
    wake: &sys::EventFd,
    counters: &Counters,
    sink: &Arc<SinkInner>,
) {
    let clock = coord.clock();
    let mut slab: Vec<Option<Slot>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut events =
        vec![sys::EpollEvent { events: 0, data: 0 }; 1024];
    let mut rbuf = vec![0u8; 64 * 1024];
    let mut global_paused = false;
    let timeout_ms = cfg.poll_interval.as_millis().max(1) as i32;

    loop {
        let n = match epoll.wait(&mut events, timeout_ms) {
            Ok(n) => n,
            Err(ref e) if interrupted(e) => 0,
            Err(_) => break,
        };

        for ev in &events[..n] {
            // Packed struct: read fields by copy only.
            let token = ev.data;
            let flags = ev.events;
            match token {
                TOK_WAKE => wake.drain(),
                TOK_LISTENER => {
                    accept_ready(
                        listener,
                        epoll,
                        cfg,
                        counters,
                        &mut slab,
                        &mut free,
                        global_paused,
                    );
                }
                _ => {
                    let slot = token as usize;
                    let after = match slab.get_mut(slot) {
                        Some(Some(Slot::Open(conn))) => conn_ready(
                            coord, &clock, cfg, counters, sink, conn,
                            slot, flags, &mut rbuf,
                        ),
                        // Stale event for a slot closed earlier in
                        // this same batch.
                        _ => After::Keep,
                    };
                    if let After::Close = after {
                        close_slot(epoll, counters, &mut slab, &mut free, slot);
                    }
                }
            }
        }

        // Route queued completions back to their connections.
        let done = std::mem::take(
            &mut *sink.done.lock().unwrap_or_else(PoisonError::into_inner),
        );
        for (token, resp) in done {
            let slot = (token >> 32) as usize;
            let corr = token as u32;
            let mut freed = false;
            let mut closed = false;
            match slab.get_mut(slot) {
                Some(Some(Slot::Open(conn))) => {
                    if resp.shed {
                        counters.sheds_out.fetch_add(1, Ordering::Relaxed);
                    } else {
                        counters
                            .responses_out
                            .fetch_add(1, Ordering::Relaxed);
                    }
                    wire::encode_response(
                        &mut conn.out,
                        &wire::WireResponse::from_infer(corr, &resp),
                    );
                    conn.inflight = conn.inflight.saturating_sub(1);
                    let flushed =
                        flush(counters, conn, cfg.write_buf_limit);
                    if let After::Close = flushed {
                        closed = true;
                    } else if conn.out.len() - conn.out_at
                        > cfg.write_buf_limit
                    {
                        // Responses are piling up faster than the
                        // client reads them: stop reading more
                        // requests from it (lifted by `flush`).
                        conn.local_paused = true;
                    }
                }
                Some(Some(Slot::Zombie { inflight })) => {
                    *inflight = inflight.saturating_sub(1);
                    freed = *inflight == 0;
                }
                _ => {}
            }
            if closed {
                close_slot(epoll, counters, &mut slab, &mut free, slot);
            }
            if freed {
                slab[slot] = None;
                free.push(slot);
            }
        }

        // Admission coupling: one poll per iteration; a flip
        // re-registers (or drops) read interest on every connection in
        // the sweep below.
        global_paused = !coord.ingress_reads_allowed();

        // Sweep: reconcile epoll interest and the paused gauge with
        // each connection's state, and finish drained connections.
        let mut to_close: Vec<usize> = Vec::new();
        for (slot, entry) in slab.iter_mut().enumerate() {
            if let Some(Slot::Open(conn)) = entry {
                if conn.draining
                    && conn.out_at == conn.out.len()
                    && conn.inflight == 0
                {
                    to_close.push(slot);
                    continue;
                }
                sync_paused(counters, conn, global_paused);
                let want = desired_interest(conn, global_paused);
                if want != conn.interest
                    && epoll.modify(conn.fd, slot as u64, want).is_ok()
                {
                    conn.interest = want;
                }
            }
        }
        for slot in to_close {
            close_slot(epoll, counters, &mut slab, &mut free, slot);
        }

        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
}

fn desired_interest(conn: &Conn, global_paused: bool) -> u32 {
    let mut want = sys::EPOLLRDHUP;
    if !conn.draining && !global_paused && !conn.local_paused {
        want |= sys::EPOLLIN;
    }
    if conn.out_at < conn.out.len() {
        want |= sys::EPOLLOUT;
    }
    want
}

/// Keep the `paused` gauge exactly equal to the number of open
/// connections whose reads are currently deregistered.
fn sync_paused(counters: &Counters, conn: &mut Conn, global_paused: bool) {
    let now = !conn.draining && (global_paused || conn.local_paused);
    if now != conn.counted_paused {
        if now {
            counters.paused.fetch_add(1, Ordering::Relaxed);
        } else {
            counters.paused.fetch_sub(1, Ordering::Relaxed);
        }
        conn.counted_paused = now;
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_ready(
    listener: &TcpListener,
    epoll: &sys::Epoll,
    cfg: &IngressConfig,
    counters: &Counters,
    slab: &mut Vec<Option<Slot>>,
    free: &mut Vec<usize>,
    global_paused: bool,
) {
    loop {
        let (sock, _peer) = match listener.accept() {
            Ok(p) => p,
            Err(ref e) if wouldblock(e) => break,
            Err(ref e) if interrupted(e) => continue,
            Err(_) => break,
        };
        let open =
            counters.active.load(Ordering::Relaxed) as usize;
        if open >= cfg.max_conns {
            // At capacity: refuse by immediate close (the kernel RST
            // tells the client more honestly than a buffered frame
            // we might never get to write).
            drop(sock);
            continue;
        }
        if sock.set_nonblocking(true).is_err() {
            continue;
        }
        let _ = sock.set_nodelay(true);
        let fd = std::os::unix::io::AsRawFd::as_raw_fd(&sock);
        let slot = match free.pop() {
            Some(s) => s,
            None => {
                slab.push(None);
                slab.len() - 1
            }
        };
        let mut conn = Box::new(Conn {
            sock,
            fd,
            dec: wire::Decoder::new(),
            out: Vec::new(),
            out_at: 0,
            inflight: 0,
            interest: 0,
            draining: false,
            local_paused: false,
            counted_paused: false,
            acct_frames: 0,
        });
        let want = desired_interest(&conn, global_paused);
        if epoll.add(fd, slot as u64, want).is_err() {
            free.push(slot);
            continue;
        }
        conn.interest = want;
        counters.accepted.fetch_add(1, Ordering::Relaxed);
        counters.active.fetch_add(1, Ordering::Relaxed);
        sync_paused(counters, &mut conn, global_paused);
        slab[slot] = Some(Slot::Open(conn));
    }
}

/// Readiness on one connection: flush pending writes, then read and
/// decode as long as the socket yields bytes.
#[allow(clippy::too_many_arguments)]
fn conn_ready(
    coord: &Coordinator,
    clock: &ClockRef,
    cfg: &IngressConfig,
    counters: &Counters,
    sink: &Arc<SinkInner>,
    conn: &mut Conn,
    slot: usize,
    flags: u32,
    rbuf: &mut [u8],
) -> After {
    if flags & sys::EPOLLERR != 0 {
        return After::Close;
    }
    if flags & sys::EPOLLOUT != 0 {
        if let After::Close = flush(counters, conn, cfg.write_buf_limit)
        {
            return After::Close;
        }
    }
    if flags & (sys::EPOLLHUP | sys::EPOLLRDHUP) != 0 {
        // Read once more below (there may be final buffered bytes),
        // then stop reading for good.
        conn.draining = true;
    }
    if flags & (sys::EPOLLIN | sys::EPOLLHUP | sys::EPOLLRDHUP) != 0 {
        loop {
            let n = match conn.sock.read(rbuf) {
                Ok(0) => {
                    conn.draining = true;
                    break;
                }
                Ok(n) => n,
                Err(ref e) if wouldblock(e) => break,
                Err(ref e) if interrupted(e) => continue,
                Err(_) => return After::Close,
            };
            counters.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
            conn.dec.extend(&rbuf[..n]);
            loop {
                match conn.dec.next() {
                    Ok(Some(wire::Frame::Request(req))) => {
                        counters
                            .frames_in
                            .fetch_add(1, Ordering::Relaxed);
                        conn.acct_frames += 1;
                        conn.inflight += 1;
                        let t_ingress = clock.now_ns();
                        // Sheds complete through the sink too, so
                        // every submit is exactly one completion —
                        // the return value is informational here.
                        let sink_dyn: Arc<dyn CompletionSink> =
                            sink.clone();
                        let _ = coord.submit_sink(
                            &req.model,
                            req.x,
                            sink_dyn,
                            submit_token(slot, req.corr),
                            t_ingress,
                        );
                    }
                    Ok(Some(wire::Frame::Response(_))) => {
                        // Clients do not send responses.
                        counters
                            .protocol_errors
                            .fetch_add(1, Ordering::Relaxed);
                        return After::Close;
                    }
                    Ok(None) => break,
                    Err(_proto) => {
                        counters
                            .protocol_errors
                            .fetch_add(1, Ordering::Relaxed);
                        return After::Close;
                    }
                }
            }
            // Per-connection write backpressure: a client that sends
            // faster than it reads responses gets its reads paused
            // (resumed by `flush` at half the cap).
            if conn.out.len() - conn.out_at > cfg.write_buf_limit {
                conn.local_paused = true;
                break;
            }
        }
    }
    After::Keep
}

/// Write as much pending output as the socket accepts; lifts a
/// write-cap pause once the backlog falls to half `write_buf_limit`.
fn flush(
    counters: &Counters,
    conn: &mut Conn,
    write_buf_limit: usize,
) -> After {
    while conn.out_at < conn.out.len() {
        match conn.sock.write(&conn.out[conn.out_at..]) {
            Ok(0) => return After::Close,
            Ok(n) => {
                conn.out_at += n;
                counters
                    .bytes_out
                    .fetch_add(n as u64, Ordering::Relaxed);
            }
            Err(ref e) if wouldblock(e) => break,
            Err(ref e) if interrupted(e) => continue,
            Err(_) => return After::Close,
        }
    }
    if conn.out_at == conn.out.len() {
        conn.out.clear();
        conn.out_at = 0;
    } else if conn.out_at >= 64 * 1024 {
        conn.out.drain(..conn.out_at);
        conn.out_at = 0;
    }
    if conn.local_paused
        && conn.out.len() - conn.out_at <= write_buf_limit / 2
    {
        conn.local_paused = false;
    }
    After::Keep
}

/// Tear down one connection. If completions are still in flight the
/// slot becomes a zombie so its token stays reserved; otherwise it
/// returns to the free list immediately.
fn close_slot(
    epoll: &sys::Epoll,
    counters: &Counters,
    slab: &mut [Option<Slot>],
    free: &mut Vec<usize>,
    slot: usize,
) {
    let entry = match slab.get_mut(slot) {
        Some(e) => e,
        None => return,
    };
    match entry.take() {
        Some(Slot::Open(conn)) => {
            let _ = epoll.delete(conn.fd);
            counters.active.fetch_sub(1, Ordering::Relaxed);
            if conn.counted_paused {
                counters.paused.fetch_sub(1, Ordering::Relaxed);
            }
            if conn.inflight > 0 {
                *entry = Some(Slot::Zombie { inflight: conn.inflight });
            } else {
                free.push(slot);
            }
            // `conn.sock` drops here, closing the fd.
        }
        // Already a zombie (or empty): put it back untouched.
        other => *entry = other,
    }
}
