//! Minimal raw Linux bindings for the ingress event loop.
//!
//! The workspace vendors no `libc` crate (offline-build policy), so the
//! handful of syscalls the readiness loop needs — epoll, eventfd and
//! the fd rlimit — are declared here directly against the C ABI that
//! `std` already links. Everything is wrapped in safe RAII types; raw
//! `unsafe` never leaks past this module.

use std::io;
use std::os::raw::{c_int, c_void};
use std::os::unix::io::RawFd;

// Readiness flags (bits of `epoll_event.events`).
pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
/// Peer closed its write half (half-close seen without a read).
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;

const RLIMIT_NOFILE: c_int = 7;

/// `struct epoll_event`. Packed on x86_64 (the kernel ABI packs it
/// there), naturally aligned elsewhere. Fields are read by copy only —
/// taking a reference into a packed struct is undefined layout.
#[derive(Clone, Copy)]
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

/// `struct rlimit` (64-bit Linux: two unsigned longs).
#[repr(C)]
#[derive(Clone, Copy, Debug)]
struct CRlimit {
    cur: u64,
    max: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(
        epfd: c_int,
        op: c_int,
        fd: c_int,
        event: *mut EpollEvent,
    ) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout: c_int,
    ) -> c_int;
    fn eventfd(initval: u32, flags: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut CRlimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const CRlimit) -> c_int;
}

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// RAII epoll instance.
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(
        &self,
        op: c_int,
        fd: RawFd,
        events: u32,
        token: u64,
    ) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) })?;
        Ok(())
    }

    /// Register `fd` with an interest mask; `token` comes back on
    /// every readiness event for it.
    pub fn add(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    /// Change an existing registration's interest mask. This is the
    /// backpressure primitive: dropping `EPOLLIN` deregisters read
    /// interest without touching the connection.
    pub fn modify(
        &self,
        fd: RawFd,
        token: u64,
        events: u32,
    ) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    /// Deregister `fd` entirely.
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block up to `timeout_ms` (-1 = forever) for readiness; fills
    /// `events` and returns how many entries are valid.
    pub fn wait(
        &self,
        events: &mut [EpollEvent],
        timeout_ms: i32,
    ) -> io::Result<usize> {
        let n = cvt(unsafe {
            epoll_wait(
                self.fd,
                events.as_mut_ptr(),
                events.len() as c_int,
                timeout_ms,
            )
        })?;
        Ok(n as usize)
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

/// Cross-thread wakeup: completion sinks signal it from worker
/// threads; the event loop keeps it registered for `EPOLLIN` and
/// drains it on wake.
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(EventFd { fd })
    }

    pub fn raw(&self) -> RawFd {
        self.fd
    }

    /// Add one to the counter, waking the poller. Infallible by
    /// design: a saturated counter (EAGAIN) is still readable, which
    /// is all a wakeup needs.
    pub fn signal(&self) {
        let one: u64 = 1;
        unsafe {
            write(self.fd, (&one as *const u64).cast::<c_void>(), 8);
        }
    }

    /// Reset the counter after a wake (nonblocking; a no-op when the
    /// counter is already zero).
    pub fn drain(&self) {
        let mut v: u64 = 0;
        unsafe {
            read(self.fd, (&mut v as *mut u64).cast::<c_void>(), 8);
        }
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

/// Soft and hard `RLIMIT_NOFILE` (open-fd budget).
#[derive(Clone, Copy, Debug)]
pub struct FdLimit {
    pub soft: u64,
    pub hard: u64,
}

/// Current fd limits for this process.
pub fn fd_limit() -> io::Result<FdLimit> {
    let mut r = CRlimit { cur: 0, max: 0 };
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut r) })?;
    Ok(FdLimit { soft: r.cur, hard: r.max })
}

/// Raise the soft fd limit to the hard limit and return the result —
/// what a 10k-connection bench needs on runners whose default soft
/// limit is 1024.
pub fn raise_fd_limit() -> io::Result<FdLimit> {
    let l = fd_limit()?;
    if l.soft < l.hard {
        let r = CRlimit { cur: l.hard, max: l.hard };
        cvt(unsafe { setrlimit(RLIMIT_NOFILE, &r) })?;
    }
    fd_limit()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eventfd_signals_epoll_and_drains() {
        let ep = Epoll::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.raw(), 7, EPOLLIN).unwrap();
        let mut buf = [EpollEvent { events: 0, data: 0 }; 8];

        // Idle: nothing ready.
        assert_eq!(ep.wait(&mut buf, 0).unwrap(), 0);

        // Signaled: readable with our token.
        ev.signal();
        assert_eq!(ep.wait(&mut buf, 100).unwrap(), 1);
        let tok = buf[0].data;
        let flags = buf[0].events;
        assert_eq!(tok, 7);
        assert_ne!(flags & EPOLLIN, 0);

        // Drained: quiet again (level-triggered, so this proves the
        // counter actually reset).
        ev.drain();
        assert_eq!(ep.wait(&mut buf, 0).unwrap(), 0);

        // Interest can be dropped and restored.
        ev.signal();
        ep.modify(ev.raw(), 7, 0).unwrap();
        assert_eq!(ep.wait(&mut buf, 0).unwrap(), 0);
        ep.modify(ev.raw(), 7, EPOLLIN).unwrap();
        assert_eq!(ep.wait(&mut buf, 100).unwrap(), 1);
        ep.delete(ev.raw()).unwrap();
    }

    #[test]
    fn fd_limit_is_sane() {
        let l = fd_limit().unwrap();
        assert!(l.soft >= 8, "soft fd limit {} absurdly low", l.soft);
        assert!(l.soft <= l.hard);
    }
}
