//! Regenerates paper Fig. 7 (percentile-clipping ablation).
use dynaprec::experiments::{figures, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    figures::fig7(&ctx).unwrap();
}
