//! Control-plane hot-path overhead (acceptance: < 5% of the batch hot
//! path). No artifacts needed: uses a synthetic model meta.
//!
//! The device loop pays three control-plane costs per batch:
//!   1. admission gate bookkeeping (router side: admit + complete),
//!   2. a scheduler read-lock + policy materialization,
//!   3. one telemetry ring push.
//! Everything else (windowing, percentiles, plan prediction) runs on
//! the control thread, off the hot path — measured here anyway for
//! visibility.
//!
//! Run: `cargo bench --bench control_plane`

use std::sync::RwLock;
use std::time::Instant;

use dynaprec::control::{
    window_stats, AdmissionConfig, AdmissionGate, BatchSample, TelemetryRing,
};
use dynaprec::coordinator::scheduler::ModelPrecision;
use dynaprec::coordinator::{EnergyPolicy, PrecisionScheduler};
use dynaprec::runtime::artifact::ModelMeta;
use dynaprec::util::stats::bench;

fn sample(i: u64) -> BatchSample {
    BatchSample {
        t_us: i,
        served: 8,
        queue_depth: 17,
        occupancy: 0.9,
        exec_us: 850.0,
        lat_mean_us: 1200.0,
        lat_max_us: 2100.0,
        energy: 2.56e5,
        device: 0,
        out_err: 0.02,
    }
}

fn main() {
    // Same synthetic profile as rust/tests/control_plane.rs.
    let meta = ModelMeta::synthetic("synth", 8, 2, 4, 64, 250.0);

    // 1. Admission gate: one admit + one completion.
    let gate = AdmissionGate::new(AdmissionConfig::default(), 0.25);
    let r_gate = bench("admission_admit_complete", || {
        let v = gate.on_submit(true);
        std::hint::black_box(v);
        gate.on_complete(1);
    });
    r_gate.report();

    // 2. Scheduler read-lock + policy fetch + e-vector materialization.
    let mut s = PrecisionScheduler::new();
    s.set(
        "synth",
        ModelPrecision {
            noise: "shot".into(),
            policy: EnergyPolicy::PerLayer(vec![16.0, 16.0]),
        },
    );
    let sched = RwLock::new(s);
    let r_sched = bench("scheduler_read_and_materialize", || {
        let g = sched.read().unwrap();
        let p = g.get("synth").unwrap();
        let e = p.policy.e_vector(&meta).unwrap();
        std::hint::black_box(e.len());
    });
    r_sched.report();

    // 3. Telemetry ring push (single writer).
    let ring = TelemetryRing::new(1024);
    let mut i = 0u64;
    let r_push = bench("telemetry_ring_push", || {
        ring.push(&sample(i));
        i += 1;
    });
    r_push.report();

    // Off-hot-path, for visibility: a full control-thread decision read
    // (snapshot + window stats over 64 batches).
    for j in 0..1024u64 {
        ring.push(&sample(j));
    }
    let r_window = bench("control_snapshot_window64", || {
        let w = window_stats(&ring.snapshot(64));
        std::hint::black_box(w.batches);
    });
    r_window.report();

    // Verdict against the acceptance bar: per-batch hot-path overhead
    // vs. a 1 ms reference batch execution (the smallest batch the
    // serving tests observe; real artifact executes are larger, making
    // the ratio smaller still).
    let per_batch =
        r_gate.p50.as_secs_f64() + r_sched.p50.as_secs_f64() + r_push.p50.as_secs_f64();
    let reference_batch_s = 1.0e-3;
    let pct = 100.0 * per_batch / reference_batch_s;

    // Measured end-to-end sanity: time 10k simulated "batches" (gate +
    // sched + push) against the pure reference loop.
    let n = 10_000u64;
    let t0 = Instant::now();
    for k in 0..n {
        let v = gate.on_submit(true);
        std::hint::black_box(v);
        gate.on_complete(1);
        let g = sched.read().unwrap();
        let p = g.get("synth").unwrap();
        std::hint::black_box(p.policy.e_vector(&meta).unwrap().len());
        ring.push(&sample(k));
    }
    let loop_per_batch = t0.elapsed().as_secs_f64() / n as f64;

    println!(
        "\ncontrol-plane hot path: {:.2} us/batch (p50 sum), {:.2} us/batch \
         (measured loop)",
        per_batch * 1e6,
        loop_per_batch * 1e6
    );
    println!(
        "overhead vs 1 ms reference batch: {pct:.3}% (acceptance < 5%)"
    );
    if pct < 5.0 {
        println!("PASS: governor overhead under the 5% bar");
    } else {
        println!("FAIL: governor overhead exceeds the 5% bar");
        std::process::exit(1);
    }
}
