//! Regenerates paper Fig. 4 (accuracy vs optical energy/MAC).
use dynaprec::experiments::{figures, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    figures::fig4(&ctx).unwrap();
}
