//! Regenerates paper Table II (min energy/MAC, 5 CV models x 3 noises).
//! Quick mode by default; DYNAPREC_FULL=1 for the recorded protocol.
//! Subset with DYNAPREC_MODELS / DYNAPREC_NOISES (comma-separated).
use dynaprec::experiments::{tables, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    let models_env = std::env::var("DYNAPREC_MODELS").unwrap_or_default();
    let noises_env = std::env::var("DYNAPREC_NOISES").unwrap_or_default();
    let models: Vec<&str> = if models_env.is_empty() {
        vec!["tiny_resnet", "tiny_mobilenet", "tiny_inception",
             "tiny_googlenet", "tiny_shufflenet"]
    } else { models_env.split(',').collect() };
    // Quick mode covers the shot-noise row set (the paper's headline
    // numbers); DYNAPREC_FULL=1 or DYNAPREC_NOISES=... adds thermal+weight.
    let noises: Vec<&str> = if !noises_env.is_empty() {
        noises_env.split(',').collect()
    } else if std::env::var("DYNAPREC_FULL").as_deref() == Ok("1") {
        vec!["shot", "thermal", "weight"]
    } else {
        vec!["shot"]
    };
    let t = std::time::Instant::now();
    tables::table2(&ctx, &models, &noises).unwrap();
    println!("[table2 done in {:?}]", t.elapsed());
}
