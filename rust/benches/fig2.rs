//! Regenerates paper Fig. 2 (noise bits per layer, fixed sigma_t).
use dynaprec::experiments::{figures, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    figures::fig2(&ctx, 1.0).unwrap();
}
