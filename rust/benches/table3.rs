//! Regenerates paper Table III (dynamic precision noise bits).
use dynaprec::experiments::{tables, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    let t = std::time::Instant::now();
    tables::table3(&ctx).unwrap();
    println!("[table3 done in {:?}]", t.elapsed());
}
