//! Regenerates paper Fig. 9 (MobileNet per-layer energy allocations).
use dynaprec::experiments::{figures, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    figures::fig_alloc(&ctx, "tiny_mobilenet").unwrap();
}
