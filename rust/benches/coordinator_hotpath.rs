//! L3 hot-path microbenchmarks: batcher, scheduler materialization,
//! redundancy planner, RNG, JSON parse — the coordinator overhead that
//! must stay well under artifact execute time (see EXPERIMENTS.md §Perf).
use std::time::Duration;

use dynaprec::analog::{plan_layer, AveragingMode, HardwareConfig};
use dynaprec::coordinator::{BatcherConfig, DynamicBatcher, EnergyPolicy};
use dynaprec::coordinator::request::{InferRequest, Responder};
use dynaprec::data::Features;
use dynaprec::runtime::artifact::ModelMeta;
use dynaprec::util::rng::Rng;
use dynaprec::util::stats::bench;

fn meta() -> ModelMeta {
    let text = std::fs::read_to_string(
        dynaprec::artifacts_dir().join("tiny_resnet.meta.json"),
    ).expect("run `make artifacts` first");
    ModelMeta::parse(&text).unwrap()
}

fn main() {
    let m = meta();

    // Batcher push+flush for a full batch of 32.
    let r = bench("batcher_push_flush_32", || {
        let mut b = DynamicBatcher::new(BatcherConfig {
            batch_size: 32,
            max_wait: Duration::from_millis(10),
        });
        let now_ns = 0u64;
        for i in 0..32 {
            let (tx, _rx) = std::sync::mpsc::channel();
            b.push(InferRequest {
                id: i,
                model: "m".into(),
                x: Features::F32(vec![0.0; 4]),
                enqueued: now_ns,
                resp: Responder::Channel(tx),
                span: None,
            });
        }
        assert!(b.try_batch(now_ns).is_some());
    });
    r.report();

    // Scheduler policy materialization (per-layer broadcast, e_len=912).
    let pl: Vec<f64> = (0..m.noise_sites().count()).map(|i| 1.0 + i as f64).collect();
    let pol = EnergyPolicy::PerLayer(pl);
    let r = bench("policy_e_vector_912ch", || {
        let e = pol.e_vector(&m).unwrap();
        std::hint::black_box(e);
    });
    r.report();

    // Redundancy planning for the whole model.
    let hw = HardwareConfig::homodyne();
    let e = pol.e_vector(&m).unwrap();
    let r = bench("redundancy_plan_model", || {
        let mut tot = 0.0;
        for (_, s) in m.noise_sites() {
            let es: Vec<f64> = e[s.e_offset..s.e_offset + s.n_channels]
                .iter().map(|&v| v as f64).collect();
            tot += plan_layer(&hw, AveragingMode::PerRowSpatial, &es,
                              s.n_dot, s.macs_per_channel, true).energy;
        }
        std::hint::black_box(tot);
    });
    r.report();

    // Gaussian fill (noise source for host-side simulations).
    let mut rng = Rng::new(1);
    let mut buf = vec![0f32; 32 * 912];
    let r = bench("gaussian_fill_29k", || {
        rng.fill_gaussian_f32(&mut buf);
    });
    r.report();

    // meta.json parse (artifact registry path).
    let text = std::fs::read_to_string(
        dynaprec::artifacts_dir().join("tiny_resnet.meta.json")).unwrap();
    let r = bench("meta_json_parse", || {
        let m = ModelMeta::parse(&text).unwrap();
        std::hint::black_box(m.e_len);
    });
    r.report();
}
