//! Regenerates paper Fig. 8 (BERT per-matmul energy).
use dynaprec::experiments::{figures, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    figures::fig8(&ctx).unwrap();
}
