//! Regenerates paper Fig. 5 (noise bits per layer, dynamic energy).
use dynaprec::experiments::{figures, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    figures::fig5(&ctx, 20.0).unwrap();
}
