//! Regenerates paper Fig. 6 (per-layer energy allocations, ResNet-like).
use dynaprec::experiments::{figures, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    figures::fig_alloc(&ctx, "tiny_resnet").unwrap();
}
