//! Regenerates paper Table IV (BERT shot-noise energy/MAC).
use dynaprec::experiments::{tables, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    let t = std::time::Instant::now();
    tables::table4(&ctx).unwrap();
    println!("[table4 done in {:?}]", t.elapsed());
}
