//! Observability hot-path overhead (acceptance: < 5% of the batch hot
//! path; span sampling <= 1% on its own). No artifacts needed: records
//! straight into an `ObsHub`.
//!
//! The serving stack pays these observability costs per dispatched
//! batch of `BATCH` requests:
//!   1. one `batch_fill` record (dispatcher side),
//!   2. `BATCH` per-request latency records (device worker),
//!   3. one energy-per-request record + one weighted out_err record +
//!      one queue-depth record (device worker, batch completion).
//! Decision-trace pushes happen on control-plane *decisions* (scale
//! steps, sheds, faults), not per batch — a push is measured and
//! charged here anyway as a worst case of one decision per batch.
//!
//! Span tracing adds, per batch at 1-in-64 sampling:
//!   4. `BATCH` sampling decisions (a hash + modulo on the router),
//!   5. `BATCH/64` expected full span records (stamps folded into the
//!      phase histograms + one seqlock ring push).
//! With sampling disabled the whole span path is one branch per
//! request — asserted to cost effectively nothing below.
//!
//! Run: `cargo bench --bench observability` (writes `BENCH_obs.json`).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use dynaprec::obs::{
    ObsHub, RequestSpan, SpanConfig, TraceKind, ERR_TICKS_PER_UNIT,
};
use dynaprec::sim::clock::WallClock;
use dynaprec::util::stats::{bench, write_bench_json};

const BATCH: u64 = 8;

fn hub() -> ObsHub {
    // Span sampling on at the production-suggested 1-in-64 rate, so the
    // span benches below exercise the real sampled path.
    ObsHub::with_spans(
        vec!["synth".to_string()],
        4,
        4096,
        4096,
        SpanConfig { sample_every: 64, seed: 0x5eed },
        Arc::new(WallClock::new()),
    )
}

/// A fully stamped span, as the device worker finalizes one.
fn span(id: u64) -> RequestSpan {
    RequestSpan {
        id,
        model: 0,
        device: 1,
        t_ingress: 1_000,
        t_submit: 1_000,
        t_enqueue: 1_000,
        t_assemble: 3_000,
        t_dispatch: 10_000,
        t_execute: 12_000,
        t_kernel: 52_000,
        t_decode: 53_000,
        t_respond: 53_000,
        digital_ns: 8_000,
        digital_aj: 64.0,
        analog_aj: 12.5,
        k_total: 96.0,
    }
}

fn main() {
    let hub = hub();
    let obs = hub.device(0);

    // 1. Dispatcher: batch-fill record.
    let r_fill = bench("batch_fill_record", || {
        hub.batch_fill.record(BATCH);
    });
    r_fill.report();

    // 2. Device worker: per-request latency records for one batch.
    let mut i = 0u64;
    let r_lat = bench("latency_record_x8", || {
        for k in 0..BATCH {
            obs.latency_us.record(1200 + (i + k) % 700);
        }
        i += 1;
    });
    r_lat.report();

    // 3. Device worker: batch-completion records (energy, weighted
    // out_err, queue depth).
    let r_done = bench("batch_completion_records", || {
        obs.energy_per_req.record(32_000);
        obs.out_err_u
            .record_n((0.021 * ERR_TICKS_PER_UNIT) as u64, BATCH);
        obs.queue_depth.record(17);
    });
    r_done.report();

    // Worst case: one decision-trace push per batch (real decision
    // rates are per control tick, orders of magnitude rarer).
    let mut j = 0u64;
    let r_trace = bench("trace_push", || {
        hub.trace.push(
            TraceKind::ScaleStep,
            Some(0),
            None,
            1.0,
            0.7,
            2_100.0 + j as f64,
            -1.0,
        );
        j += 1;
    });
    r_trace.report();

    // 4. Router: the per-request sampling decision at 1-in-64 — the
    // only cost the unsampled 63/64 majority ever pays.
    let cfg = hub.span_cfg();
    let mut id = 0u64;
    let r_sample = bench("span_sampled_check_x8", || {
        for _ in 0..BATCH {
            std::hint::black_box(cfg.sampled(id));
            id += 1;
        }
    });
    r_sample.report();

    // ... and with sampling disabled the check must reduce to a single
    // branch on an immutable config (the "0-cost when off" guarantee).
    let off = SpanConfig::default();
    let mut od = 0u64;
    let r_off = bench("span_sampled_check_disabled_x8", || {
        for _ in 0..BATCH {
            std::hint::black_box(off.sampled(od));
            od += 1;
        }
    });
    r_off.report();

    // 5. Device worker: one full span finalization — eight phase
    // histogram folds, two plane folds, one seqlock ring push. Paid by
    // 1-in-64 requests; amortized per batch below.
    let mut sid = 0u64;
    let r_span = bench("span_record", || {
        hub.record_span(span(sid));
        sid += 1;
    });
    r_span.report();

    // Off-hot-path, for visibility: a full hub snapshot (merge across
    // devices + trace digest) as taken by `Coordinator::stats`.
    let r_snap = bench("hub_snapshot", || {
        std::hint::black_box(hub.snapshot().latency_us.count());
    });
    r_snap.report();

    // Verdict against the acceptance bar: per-batch hot-path overhead
    // vs. a 1 ms reference batch execution (the smallest batch the
    // serving tests observe; real artifact executes are larger, making
    // the ratio smaller still).
    let per_batch = r_fill.p50.as_secs_f64()
        + r_lat.p50.as_secs_f64()
        + r_done.p50.as_secs_f64()
        + r_trace.p50.as_secs_f64();
    let reference_batch_s = 1.0e-3;
    let pct = 100.0 * per_batch / reference_batch_s;

    // Span budget: 8 sampling checks plus the expected 8/64 span
    // records per batch, against the same 1 ms reference batch.
    let span_per_batch = r_sample.p50.as_secs_f64()
        + r_span.p50.as_secs_f64() * (BATCH as f64 / 64.0);
    let span_pct = 100.0 * span_per_batch / reference_batch_s;
    let off_us = r_off.p50.as_secs_f64() * 1e6;

    // Measured end-to-end sanity: time 10k simulated "batches" (fill +
    // 8 latencies + completion + trace) in one loop.
    let n = 10_000u64;
    let t0 = Instant::now();
    for k in 0..n {
        hub.batch_fill.record(BATCH);
        for r in 0..BATCH {
            obs.latency_us.record(1200 + (k + r) % 700);
        }
        obs.energy_per_req.record(32_000);
        obs.out_err_u
            .record_n((0.021 * ERR_TICKS_PER_UNIT) as u64, BATCH);
        obs.queue_depth.record(17);
        hub.trace.push(
            TraceKind::ScaleStep,
            Some(0),
            None,
            1.0,
            0.7,
            2_100.0,
            -1.0,
        );
    }
    let loop_per_batch = t0.elapsed().as_secs_f64() / n as f64;

    println!(
        "\nobservability hot path: {:.3} us/batch (p50 sum), {:.3} us/batch \
         (measured loop)",
        per_batch * 1e6,
        loop_per_batch * 1e6
    );
    println!(
        "overhead vs 1 ms reference batch: {pct:.3}% (acceptance < 5%)"
    );
    println!(
        "span sampling at 1/64: {:.3} us/batch = {span_pct:.4}% \
         (acceptance <= 1%); disabled check: {off_us:.4} us/batch",
        span_per_batch * 1e6
    );

    let results = [
        r_fill, r_lat, r_done, r_trace, r_sample, r_off, r_span, r_snap,
    ];
    let path = Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_obs.json"
    ));
    write_bench_json(
        path,
        "observability",
        &results,
        &[
            ("hotpath_pct_of_1ms_batch", pct),
            ("span_pct_of_1ms_batch", span_pct),
            ("span_us_per_batch_1_in_64", span_per_batch * 1e6),
            ("span_disabled_check_us_per_batch", off_us),
        ],
    )
    .expect("write BENCH_obs.json");
    println!("wrote {}", path.display());

    let mut pass = true;
    if pct >= 5.0 {
        println!("FAIL: observability overhead exceeds the 5% bar");
        pass = false;
    }
    if span_pct > 1.0 {
        println!("FAIL: span sampling exceeds its 1% budget");
        pass = false;
    }
    // "0-cost disabled": one branch per request. 1 us for a whole batch
    // of 8 checks is two orders of magnitude of slack over the real
    // cost, while still catching an accidental hash-on-every-request.
    if off_us > 1.0 {
        println!("FAIL: disabled span check is not free");
        pass = false;
    }
    if pass {
        println!("PASS: observability overhead under the bars");
    } else {
        std::process::exit(1);
    }
}
