//! Observability hot-path overhead (acceptance: < 5% of the batch hot
//! path). No artifacts needed: records straight into an `ObsHub`.
//!
//! The serving stack pays these observability costs per dispatched
//! batch of `BATCH` requests:
//!   1. one `batch_fill` record (dispatcher side),
//!   2. `BATCH` per-request latency records (device worker),
//!   3. one energy-per-request record + one weighted out_err record +
//!      one queue-depth record (device worker, batch completion).
//! Decision-trace pushes happen on control-plane *decisions* (scale
//! steps, sheds, faults), not per batch — a push is measured and
//! charged here anyway as a worst case of one decision per batch.
//!
//! Run: `cargo bench --bench observability`

use std::sync::Arc;
use std::time::Instant;

use dynaprec::obs::{ObsHub, TraceKind, ERR_TICKS_PER_UNIT};
use dynaprec::sim::clock::WallClock;
use dynaprec::util::stats::bench;

const BATCH: u64 = 8;

fn hub() -> ObsHub {
    ObsHub::new(
        vec!["synth".to_string()],
        4,
        4096,
        Arc::new(WallClock::new()),
    )
}

fn main() {
    let hub = hub();
    let obs = hub.device(0);

    // 1. Dispatcher: batch-fill record.
    let r_fill = bench("batch_fill_record", || {
        hub.batch_fill.record(BATCH);
    });
    r_fill.report();

    // 2. Device worker: per-request latency records for one batch.
    let mut i = 0u64;
    let r_lat = bench("latency_record_x8", || {
        for k in 0..BATCH {
            obs.latency_us.record(1200 + (i + k) % 700);
        }
        i += 1;
    });
    r_lat.report();

    // 3. Device worker: batch-completion records (energy, weighted
    // out_err, queue depth).
    let r_done = bench("batch_completion_records", || {
        obs.energy_per_req.record(32_000);
        obs.out_err_u
            .record_n((0.021 * ERR_TICKS_PER_UNIT) as u64, BATCH);
        obs.queue_depth.record(17);
    });
    r_done.report();

    // Worst case: one decision-trace push per batch (real decision
    // rates are per control tick, orders of magnitude rarer).
    let mut j = 0u64;
    let r_trace = bench("trace_push", || {
        hub.trace.push(
            TraceKind::ScaleStep,
            Some(0),
            None,
            1.0,
            0.7,
            2_100.0 + j as f64,
            -1.0,
        );
        j += 1;
    });
    r_trace.report();

    // Off-hot-path, for visibility: a full hub snapshot (merge across
    // devices + trace digest) as taken by `Coordinator::stats`.
    let r_snap = bench("hub_snapshot", || {
        std::hint::black_box(hub.snapshot().latency_us.count());
    });
    r_snap.report();

    // Verdict against the acceptance bar: per-batch hot-path overhead
    // vs. a 1 ms reference batch execution (the smallest batch the
    // serving tests observe; real artifact executes are larger, making
    // the ratio smaller still).
    let per_batch = r_fill.p50.as_secs_f64()
        + r_lat.p50.as_secs_f64()
        + r_done.p50.as_secs_f64()
        + r_trace.p50.as_secs_f64();
    let reference_batch_s = 1.0e-3;
    let pct = 100.0 * per_batch / reference_batch_s;

    // Measured end-to-end sanity: time 10k simulated "batches" (fill +
    // 8 latencies + completion + trace) in one loop.
    let n = 10_000u64;
    let t0 = Instant::now();
    for k in 0..n {
        hub.batch_fill.record(BATCH);
        for r in 0..BATCH {
            obs.latency_us.record(1200 + (k + r) % 700);
        }
        obs.energy_per_req.record(32_000);
        obs.out_err_u
            .record_n((0.021 * ERR_TICKS_PER_UNIT) as u64, BATCH);
        obs.queue_depth.record(17);
        hub.trace.push(
            TraceKind::ScaleStep,
            Some(0),
            None,
            1.0,
            0.7,
            2_100.0,
            -1.0,
        );
    }
    let loop_per_batch = t0.elapsed().as_secs_f64() / n as f64;

    println!(
        "\nobservability hot path: {:.3} us/batch (p50 sum), {:.3} us/batch \
         (measured loop)",
        per_batch * 1e6,
        loop_per_batch * 1e6
    );
    println!(
        "overhead vs 1 ms reference batch: {pct:.3}% (acceptance < 5%)"
    );
    if pct < 5.0 {
        println!("PASS: observability overhead under the 5% bar");
    } else {
        println!("FAIL: observability overhead exceeds the 5% bar");
        std::process::exit(1);
    }
}
