//! Regenerates paper Table I (thermal noise vs equivalent bit precision).
use dynaprec::experiments::{tables, ExpCtx};
fn main() {
    let ctx = ExpCtx::new().expect("artifacts missing — run `make artifacts`");
    let t = std::time::Instant::now();
    tables::table1(&ctx).unwrap();
    println!("[table1 done in {:?}]", t.elapsed());
}
