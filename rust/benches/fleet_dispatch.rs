//! Fleet dispatch throughput (acceptance: least-queue-depth dispatch
//! over 4 devices sustains >= 2x the single-device batch throughput at
//! equal precision scale). No artifacts needed: synthetic bundles with
//! simulated device time, so throughput is bounded by the modeled
//! hardware (32 cycles/sample x 4us/cycle = 128us of device time per
//! sample at full precision), not by host compute.
//!
//! Method: submit a fixed backlog up front (closed-loop saturation),
//! then time the steady-state segment between 1/6 and 5/6 of the
//! backlog by polling the fleet's served counter — warmup and drain
//! tails are excluded from the measurement.
//!
//! Run: `cargo bench --bench fleet_dispatch`

use std::path::Path;
use std::time::{Duration, Instant};

use dynaprec::analog::{AveragingMode, DeviceModel, HardwareConfig};
use dynaprec::backend::BackendKind;
use dynaprec::coordinator::scheduler::ModelPrecision;
use dynaprec::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DeviceSpec,
    DispatchPolicy, EnergyPolicy, FleetConfig, PrecisionScheduler,
};
use dynaprec::data::Features;
use dynaprec::runtime::artifact::{ModelBundle, ModelMeta};
use dynaprec::util::stats::{write_bench_json, BenchResult};

const MODEL: &str = "synth";

fn hw() -> HardwareConfig {
    HardwareConfig {
        array_rows: 256,
        array_cols: 256,
        cycle_ns: 4000.0,
        base_energy_aj: 1.0,
        model: DeviceModel::Homodyne,
    }
}

fn coordinator(n_devices: usize) -> Coordinator {
    let meta = ModelMeta::synthetic(MODEL, 8, 2, 4, 64, 250.0);
    let mut sched = PrecisionScheduler::new();
    sched.set(
        MODEL,
        ModelPrecision {
            noise: "shot".into(),
            policy: EnergyPolicy::PerLayer(vec![16.0, 16.0]),
        },
    );
    let devices: Vec<DeviceSpec> = (0..n_devices)
        .map(|i| {
            DeviceSpec::new(format!("dev-{i}"), hw(), AveragingMode::Time)
                .with_backend(BackendKind::NativeAnalog {
                    simulate_time: true,
                })
        })
        .collect();
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig {
            batch_size: 8,
            max_wait: Duration::from_millis(3),
        },
        averaging: AveragingMode::Time,
        fleet: FleetConfig {
            devices,
            policy: DispatchPolicy::LeastQueueDepth,
        },
        ..Default::default()
    };
    Coordinator::start(vec![ModelBundle::synthetic(meta)], sched, cfg)
        .unwrap()
}

/// Wait (polling) until `served` crosses `target`; returns the instant.
fn time_to_serve(coord: &Coordinator, target: u64) -> Instant {
    loop {
        if coord.stats().served >= target {
            return Instant::now();
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Steady-state samples/s over the middle of a fixed backlog, timed
/// segment by segment so the emitted percentiles summarize a real
/// distribution. Returns (samples/s, per-sample seconds per segment).
fn throughput(n_devices: usize, backlog: u64) -> (f64, Vec<f64>) {
    let coord = coordinator(n_devices);
    for _ in 0..backlog {
        drop(coord.submit(MODEL, Features::F32(vec![0.0; 4])));
    }
    // 8 serve marks across the steady middle -> 7 timing segments.
    let lo = backlog / 6;
    let hi = backlog * 5 / 6;
    let segments = 7u64;
    let mut marks = Vec::with_capacity(segments as usize + 1);
    for i in 0..=segments {
        let target = lo + (hi - lo) * i / segments;
        marks.push((target, time_to_serve(&coord, target)));
    }
    let stats = coord.shutdown();
    assert_eq!(stats.shed, 0, "unbounded queues must not shed");
    assert_eq!(stats.scales[MODEL], 1.0, "equal precision scale");
    let samples: Vec<f64> = marks
        .windows(2)
        .map(|w| {
            let served = (w[1].0 - w[0].0).max(1) as f64;
            (w[1].1 - w[0].1).as_secs_f64() / served
        })
        .collect();
    let (t_lo, t_hi) = (marks[0].1, marks[segments as usize].1);
    ((hi - lo) as f64 / (t_hi - t_lo).as_secs_f64(), samples)
}

fn main() {
    // At full precision a sample costs 32 cycles x 4us = 128us of
    // device time; one device sustains ~7.8k samples/s.
    let (single, single_s) = throughput(1, 12_000);
    let (quad, quad_s) = throughput(4, 24_000);
    let speedup = quad / single;
    println!(
        "single-device: {single:.0} samples/s\n\
         4-device (least-queue-depth): {quad:.0} samples/s\n\
         speedup: {speedup:.2}x (acceptance >= 2x)"
    );
    // Perf trajectory: the checked-in BENCH_fleet.json is regenerated
    // by the CI bench job, so dispatch-rate changes show up in review.
    // Each result carries its real per-segment timing distribution; the
    // emitter rejects single-sample (fabricated) percentiles.
    let results = [
        BenchResult::from_samples(
            "single_device_per_sample",
            8_000,
            &single_s,
        ),
        BenchResult::from_samples("quad_fleet_per_sample", 16_000, &quad_s),
    ];
    let path = Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_fleet.json"
    ));
    write_bench_json(
        path,
        "fleet_dispatch",
        &results,
        &[
            ("single_device_samples_per_s", single),
            ("quad_fleet_samples_per_s", quad),
            ("speedup", speedup),
        ],
    )
    .expect("write BENCH_fleet.json");
    println!("wrote {}", path.display());

    if speedup >= 2.0 {
        println!("PASS: fleet dispatch scales past the 2x bar");
    } else {
        println!("FAIL: fleet dispatch under the 2x bar");
        std::process::exit(1);
    }
}
