//! Native-backend throughput.
//!
//! Two measurements, two enforced bars:
//!
//! 1. Raw kernel rate: single-thread noisy-GEMM samples/s with the
//!   K-repetition noise folded in. Enforced >= 4x the checked-in
//!   pre-fusion baseline (`KERNEL_BASELINE_SAMPLES_PER_S`, measured
//!   before the fused kernel + batched sampling landed), and it must
//!   exceed the *modeled analog device* rate — host numerics, not the
//!   simulated hardware, must never bound a simulated fleet.
//! 2. Fleet bar: full coordinator stack over native devices with
//!   simulated analog time (32 cycles/sample x 4us = 128us/sample at
//!   full precision), single device vs 4 devices, >= 2x enforced.
//!
//! Timing is recorded per chunk of iterations (kernel) and per backlog
//! segment (fleet), so the emitted percentiles summarize a real
//! distribution — `write_bench_json` rejects single-sample results.
//!
//! Run: `cargo bench --bench native_backend`

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dynaprec::analog::{AveragingMode, DeviceModel, HardwareConfig};
use dynaprec::backend::{
    kernel_flavor, BackendKind, BatchJob, ExecutionBackend,
    NativeAnalogBackend, NativeModelSet,
};
use dynaprec::coordinator::scheduler::ModelPrecision;
use dynaprec::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DeviceSpec,
    DispatchPolicy, EnergyPolicy, FleetConfig, PrecisionScheduler,
};
use dynaprec::data::Features;
use dynaprec::runtime::artifact::{ModelBundle, ModelMeta};
use dynaprec::util::stats::{write_bench_json, BenchResult};

const MODEL: &str = "synth";
const BATCH: usize = 8;

fn meta() -> ModelMeta {
    ModelMeta::synthetic(MODEL, BATCH, 2, 4, 64, 250.0)
}

fn hw() -> HardwareConfig {
    HardwareConfig {
        array_rows: 256,
        array_cols: 256,
        cycle_ns: 4000.0,
        base_energy_aj: 1.0,
        model: DeviceModel::Homodyne,
    }
}

/// Measured single-thread kernel rate of the pre-fusion kernel
/// (separate GEMM / weight-noise / additive-noise sweeps, per-element
/// polar Gaussian, per-batch dW allocation), checked in when the fused
/// kernel landed. The current kernel must clear 4x this.
const KERNEL_BASELINE_SAMPLES_PER_S: f64 = 412_387.2;

/// Single-thread native kernel rate: noisy batches/s through the
/// backend alone, no serving stack. Returns (samples/s, mean out_err,
/// per-sample seconds per timed chunk).
fn kernel_rate() -> (f64, f64, Vec<f64>) {
    let m = meta();
    let natives = Arc::new(NativeModelSet::build([&m]));
    let bundle = ModelBundle::synthetic(meta());
    let e = m.broadcast_per_layer(&[16.0, 16.0]).unwrap();
    let mut backend =
        NativeAnalogBackend::new(hw(), AveragingMode::Time, natives);
    let x = Features::F32(vec![0.25; BATCH * 4]);
    let (chunks, per_chunk) = (100u32, 20u32);
    let mut err_sum = 0.0f64;
    let mut seed = 0u32;
    let mut samples = Vec::with_capacity(chunks as usize);
    let mut total_secs = 0.0f64;
    for _ in 0..chunks {
        let t0 = Instant::now();
        for _ in 0..per_chunk {
            let out = backend.execute(&BatchJob {
                bundle: &bundle,
                x: &x,
                n_real: BATCH,
                seed,
                e: Some(&e),
                tag: "shot.fwd",
            });
            assert!(out.logits.is_ok());
            err_sum += out.out_err as f64;
            seed += 1;
        }
        let secs = t0.elapsed().as_secs_f64();
        total_secs += secs;
        samples.push(secs / (per_chunk as f64 * BATCH as f64));
    }
    let n = (chunks * per_chunk) as f64;
    (n * BATCH as f64 / total_secs, err_sum / n, samples)
}

fn coordinator(n_devices: usize) -> Coordinator {
    let mut sched = PrecisionScheduler::new();
    sched.set(
        MODEL,
        ModelPrecision {
            noise: "shot".into(),
            policy: EnergyPolicy::PerLayer(vec![16.0, 16.0]),
        },
    );
    let devices: Vec<DeviceSpec> = (0..n_devices)
        .map(|i| {
            DeviceSpec::new(format!("native-{i}"), hw(), AveragingMode::Time)
                .with_backend(BackendKind::NativeAnalog {
                    simulate_time: true,
                })
        })
        .collect();
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig {
            batch_size: BATCH,
            max_wait: Duration::from_millis(3),
        },
        averaging: AveragingMode::Time,
        fleet: FleetConfig {
            devices,
            policy: DispatchPolicy::LeastQueueDepth,
        },
        ..Default::default()
    };
    Coordinator::start(vec![ModelBundle::synthetic(meta())], sched, cfg)
        .unwrap()
}

fn time_to_serve(coord: &Coordinator, target: u64) -> Instant {
    loop {
        if coord.stats().served >= target {
            return Instant::now();
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Steady-state samples/s over the middle of a fixed backlog, timed
/// segment by segment. Returns (samples/s, per-sample seconds per
/// segment).
fn throughput(n_devices: usize, backlog: u64) -> (f64, Vec<f64>) {
    let coord = coordinator(n_devices);
    for _ in 0..backlog {
        drop(coord.submit(MODEL, Features::F32(vec![0.25; 4])));
    }
    // 8 serve marks across the steady middle -> 7 timing segments.
    let lo = backlog / 6;
    let hi = backlog * 5 / 6;
    let segments = 7u64;
    let mut marks = Vec::with_capacity(segments as usize + 1);
    for i in 0..=segments {
        let target = lo + (hi - lo) * i / segments;
        marks.push((target, time_to_serve(&coord, target)));
    }
    let stats = coord.shutdown();
    assert_eq!(stats.shed, 0, "unbounded queues must not shed");
    assert_eq!(stats.scales[MODEL], 1.0, "equal precision scale");
    assert!(
        stats.window.mean_out_err.is_some(),
        "native fleet must measure output error"
    );
    let samples: Vec<f64> = marks
        .windows(2)
        .map(|w| {
            let served = (w[1].0 - w[0].0).max(1) as f64;
            (w[1].1 - w[0].1).as_secs_f64() / served
        })
        .collect();
    let (t_lo, t_hi) = (marks[0].1, marks[segments as usize].1);
    ((hi - lo) as f64 / (t_hi - t_lo).as_secs_f64(), samples)
}

fn main() {
    let (kernel, mean_err, kernel_samples) = kernel_rate();
    let kernel_speedup = kernel / KERNEL_BASELINE_SAMPLES_PER_S;
    println!(
        "native kernel (1 thread, {} flavor): {kernel:.0} noisy \
         samples/s (mean out_err {mean_err:.4}, {kernel_speedup:.2}x \
         the pre-fusion baseline, acceptance >= 4x)",
        kernel_flavor()
    );
    // The *simulated analog device* serves 128us of modeled device
    // time per sample at full precision (32 cycles x 4us). That is a
    // model of the accelerator being simulated, NOT a bound on the
    // host kernel: the host numerics must outrun it by a wide margin
    // so that simulated-fleet throughput is bounded by the modeled
    // hardware, never by host compute.
    let modeled_device = 1e9 / (32.0 * 4000.0);
    println!(
        "modeled analog device rate: {modeled_device:.0} samples/s \
         per device (simulated-time pacing, not a host ceiling)"
    );

    let (single, single_samples) = throughput(1, 12_000);
    let (quad, quad_samples) = throughput(4, 24_000);
    let speedup = quad / single;
    println!(
        "single native device: {single:.0} samples/s\n\
         4-device native fleet (least-queue-depth): {quad:.0} samples/s\n\
         speedup: {speedup:.2}x (acceptance >= 2x)"
    );

    // Perf trajectory: the checked-in BENCH_kernel.json is regenerated
    // by the CI bench job, so kernel-rate changes show up in review.
    // Every result carries its real per-chunk/per-segment timing
    // distribution; the emitter rejects fabricated percentiles.
    let results = [
        BenchResult::from_samples(
            "native_kernel_per_sample",
            2_000 * BATCH,
            &kernel_samples,
        ),
        BenchResult::from_samples(
            "single_device_per_sample",
            8_000,
            &single_samples,
        ),
        BenchResult::from_samples(
            "quad_fleet_per_sample",
            16_000,
            &quad_samples,
        ),
    ];
    let path = Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_kernel.json"
    ));
    write_bench_json(
        path,
        "native_backend",
        &results,
        &[
            ("kernel_samples_per_s", kernel),
            ("kernel_mean_out_err", mean_err),
            (
                "kernel_baseline_samples_per_s",
                KERNEL_BASELINE_SAMPLES_PER_S,
            ),
            ("kernel_speedup_vs_baseline", kernel_speedup),
            ("modeled_analog_device_samples_per_s", modeled_device),
            ("single_device_samples_per_s", single),
            ("quad_fleet_samples_per_s", quad),
            ("speedup", speedup),
        ],
    )
    .expect("write BENCH_kernel.json");
    println!("wrote {}", path.display());

    let mut pass = true;
    if kernel <= modeled_device {
        println!(
            "FAIL: host kernel ({kernel:.0}/s) does not outrun the \
             modeled analog device ({modeled_device:.0}/s) — host \
             compute would bound the simulated fleet"
        );
        pass = false;
    }
    if kernel_speedup < 4.0 {
        println!(
            "FAIL: kernel at {kernel_speedup:.2}x the pre-fusion \
             baseline, bar is 4x"
        );
        pass = false;
    }
    if speedup < 2.0 {
        println!("FAIL: native fleet under the 2x bar");
        pass = false;
    }
    if !pass {
        std::process::exit(1);
    }
    println!(
        "PASS: kernel {kernel_speedup:.2}x baseline, fleet \
         {speedup:.2}x single device"
    );
}
