//! Native-backend throughput (acceptance: a 4-device native fleet
//! sustains >= 2x single native-device throughput at equal precision,
//! matching the `fleet_dispatch` pattern).
//!
//! Two measurements:
//!
//! 1. Raw kernel rate: single-thread noisy-GEMM samples/s with the
//!   K-repetition noise folded in (informational — shows the numerics
//!   are far cheaper than the modeled analog device time, so the
//!   fleet's scaling is bounded by the modeled hardware, not the host).
//! 2. Fleet bar: full coordinator stack over native devices with
//!   simulated analog time (32 cycles/sample x 4us = 128us/sample at
//!   full precision), single device vs 4 devices, >= 2x enforced.
//!
//! Run: `cargo bench --bench native_backend`

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dynaprec::analog::{AveragingMode, DeviceModel, HardwareConfig};
use dynaprec::backend::{
    BackendKind, BatchJob, ExecutionBackend, NativeAnalogBackend,
    NativeModelSet,
};
use dynaprec::coordinator::scheduler::ModelPrecision;
use dynaprec::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DeviceSpec,
    DispatchPolicy, EnergyPolicy, FleetConfig, PrecisionScheduler,
};
use dynaprec::data::Features;
use dynaprec::runtime::artifact::{ModelBundle, ModelMeta};
use dynaprec::util::stats::{write_bench_json, BenchResult};

const MODEL: &str = "synth";
const BATCH: usize = 8;

fn meta() -> ModelMeta {
    ModelMeta::synthetic(MODEL, BATCH, 2, 4, 64, 250.0)
}

fn hw() -> HardwareConfig {
    HardwareConfig {
        array_rows: 256,
        array_cols: 256,
        cycle_ns: 4000.0,
        base_energy_aj: 1.0,
        model: DeviceModel::Homodyne,
    }
}

/// Single-thread native kernel rate: noisy batches/s through the
/// backend alone, no serving stack.
fn kernel_rate() -> (f64, f64) {
    let m = meta();
    let natives = Arc::new(NativeModelSet::build([&m]));
    let bundle = ModelBundle::synthetic(meta());
    let e = m.broadcast_per_layer(&[16.0, 16.0]).unwrap();
    let mut backend =
        NativeAnalogBackend::new(hw(), AveragingMode::Time, natives);
    let x = Features::F32(vec![0.25; BATCH * 4]);
    let iters = 2_000u32;
    let t0 = Instant::now();
    let mut err_sum = 0.0f64;
    for i in 0..iters {
        let out = backend.execute(&BatchJob {
            bundle: &bundle,
            x: &x,
            n_real: BATCH,
            seed: i,
            e: Some(&e),
            tag: "shot.fwd",
        });
        assert!(out.logits.is_ok());
        err_sum += out.out_err as f64;
    }
    let secs = t0.elapsed().as_secs_f64();
    (iters as f64 * BATCH as f64 / secs, err_sum / iters as f64)
}

fn coordinator(n_devices: usize) -> Coordinator {
    let mut sched = PrecisionScheduler::new();
    sched.set(
        MODEL,
        ModelPrecision {
            noise: "shot".into(),
            policy: EnergyPolicy::PerLayer(vec![16.0, 16.0]),
        },
    );
    let devices: Vec<DeviceSpec> = (0..n_devices)
        .map(|i| {
            DeviceSpec::new(format!("native-{i}"), hw(), AveragingMode::Time)
                .with_backend(BackendKind::NativeAnalog {
                    simulate_time: true,
                })
        })
        .collect();
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig {
            batch_size: BATCH,
            max_wait: Duration::from_millis(3),
        },
        averaging: AveragingMode::Time,
        fleet: FleetConfig {
            devices,
            policy: DispatchPolicy::LeastQueueDepth,
        },
        ..Default::default()
    };
    Coordinator::start(vec![ModelBundle::synthetic(meta())], sched, cfg)
        .unwrap()
}

fn time_to_serve(coord: &Coordinator, target: u64) -> Instant {
    loop {
        if coord.stats().served >= target {
            return Instant::now();
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Steady-state samples/s over the middle of a fixed backlog.
fn throughput(n_devices: usize, backlog: u64) -> f64 {
    let coord = coordinator(n_devices);
    for _ in 0..backlog {
        drop(coord.submit(MODEL, Features::F32(vec![0.25; 4])));
    }
    let lo = backlog / 6;
    let hi = backlog * 5 / 6;
    let t_lo = time_to_serve(&coord, lo);
    let t_hi = time_to_serve(&coord, hi);
    let stats = coord.shutdown();
    assert_eq!(stats.shed, 0, "unbounded queues must not shed");
    assert_eq!(stats.scales[MODEL], 1.0, "equal precision scale");
    assert!(
        stats.window.mean_out_err.is_some(),
        "native fleet must measure output error"
    );
    (hi - lo) as f64 / (t_hi - t_lo).as_secs_f64()
}

fn main() {
    let (kernel, mean_err) = kernel_rate();
    println!(
        "native kernel (1 thread): {kernel:.0} noisy samples/s \
         (mean out_err {mean_err:.4})"
    );
    // 128us of modeled device time per sample at full precision: the
    // kernel above must outrun that by a wide margin for the modeled
    // hardware (not host compute) to bound fleet throughput.
    let modeled_per_dev = 1e9 / (32.0 * 4000.0);
    println!(
        "modeled device ceiling: {modeled_per_dev:.0} samples/s per device"
    );

    let single = throughput(1, 12_000);
    let quad = throughput(4, 24_000);
    let speedup = quad / single;
    println!(
        "single native device: {single:.0} samples/s\n\
         4-device native fleet (least-queue-depth): {quad:.0} samples/s\n\
         speedup: {speedup:.2}x (acceptance >= 2x)"
    );

    // Perf trajectory: the checked-in BENCH_kernel.json is regenerated
    // by the CI bench job, so kernel-rate changes show up in review.
    // Throughput summaries carry the steady-state per-sample time in
    // every percentile field (a rate has no per-iteration spread).
    let per_sample = |name: &str, rate: f64, iters: usize| {
        let d = Duration::from_secs_f64(1.0 / rate);
        BenchResult {
            name: name.to_string(),
            iters,
            mean: d,
            p50: d,
            p95: d,
            min: d,
        }
    };
    let results = [
        per_sample("native_kernel_per_sample", kernel, 2_000 * BATCH),
        per_sample("single_device_per_sample", single, 8_000),
        per_sample("quad_fleet_per_sample", quad, 16_000),
    ];
    let path = Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_kernel.json"
    ));
    write_bench_json(
        path,
        "native_backend",
        &results,
        &[
            ("kernel_samples_per_s", kernel),
            ("kernel_mean_out_err", mean_err),
            ("modeled_ceiling_samples_per_s", modeled_per_dev),
            ("single_device_samples_per_s", single),
            ("quad_fleet_samples_per_s", quad),
            ("speedup", speedup),
        ],
    )
    .expect("write BENCH_kernel.json");
    println!("wrote {}", path.display());

    if speedup >= 2.0 {
        println!("PASS: native fleet scales past the 2x bar");
    } else {
        println!("FAIL: native fleet under the 2x bar");
        std::process::exit(1);
    }
}
